from repro.optim.optimizers import (adam, apply_updates, fedadam_server,
                                    fedavgm_server, fedyogi_server, momentum,
                                    sgd, tree_add, tree_scale, tree_sub,
                                    tree_zeros_like, yogi)

__all__ = ["sgd", "momentum", "adam", "yogi", "fedadam_server",
           "fedavgm_server", "fedyogi_server", "apply_updates", "tree_add",
           "tree_sub", "tree_scale", "tree_zeros_like"]
