from repro.optim.optimizers import (adam, apply_updates, fedadam_server,
                                    momentum, sgd, tree_add, tree_scale,
                                    tree_sub, tree_zeros_like)

__all__ = ["sgd", "momentum", "adam", "fedadam_server", "apply_updates",
           "tree_add", "tree_sub", "tree_scale", "tree_zeros_like"]
