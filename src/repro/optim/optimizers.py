"""Hand-rolled optimizers (optax is not available offline).

Each optimizer is an ``(init_fn, update_fn)`` pair operating on pytrees:
``state = init(params)``; ``updates, state = update(grads, state, params, lr)``.
Updates follow the optax convention (add them to params).

Clients use plain SGD per the paper (no state). The server update is
averaging (FedAvg) or, beyond-paper, FedAdam (Reddi et al., 2021) applied to
the averaged client delta.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# pytree arithmetic
# ---------------------------------------------------------------------------

def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# SGD (stateless) — the client optimizer in FedAvg
# ---------------------------------------------------------------------------

def sgd():
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return tree_scale(grads, -lr), state

    return init, update


def momentum(beta: float = 0.9):
    def init(params):
        return tree_zeros_like(params)

    def update(grads, m, params, lr):
        m = jax.tree.map(lambda mi, g: beta * mi + g, m, grads)
        return tree_scale(m, -lr), m

    return init, update


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda mi, vi: -lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps), m, v)
        return upd, {"m": m, "v": v, "t": t}

    return init, update


def yogi(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-3):
    """Yogi (Zaheer et al., 2018) — Adam with an additive, sign-controlled
    second-moment update: v += -(1-b2) * sign(v - g^2) * g^2. The bounded
    per-step change to v makes it less eager than Adam when gradients spike,
    which suits the sparse, bursty pseudo-gradients of federated rounds."""
    def init(params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(
            lambda vi, g: vi - (1 - b2) * jnp.sign(vi - jnp.square(g))
            * jnp.square(g),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda mi, vi: -lr * (mi / bc1) / (jnp.sqrt(jnp.maximum(vi, 0.0))
                                               + eps), m, v)
        return upd, {"m": m, "v": v, "t": t}

    return init, update


def fedadam_server(b1: float = 0.9, b2: float = 0.99, eps: float = 1e-3):
    """Server-side Adam on the averaged client delta (beyond-paper)."""
    return adam(b1=b1, b2=b2, eps=eps)


def fedavgm_server(beta: float = 0.9):
    """Server momentum on the averaged client delta (Hsu et al., 2019)."""
    return momentum(beta=beta)


def fedyogi_server(b1: float = 0.9, b2: float = 0.99, eps: float = 1e-3):
    """Server-side Yogi on the averaged client delta (Reddi et al., 2021)."""
    return yogi(b1=b1, b2=b2, eps=eps)
