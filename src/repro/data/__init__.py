from repro.data import partition, pipeline, synthetic
from repro.data.population import PopulationView
from repro.data.synthetic import FederatedData, make_lm_clients, make_paper_task

__all__ = ["partition", "pipeline", "synthetic", "FederatedData",
           "make_paper_task", "make_lm_clients", "PopulationView"]
