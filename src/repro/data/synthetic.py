"""Synthetic same-shape stand-ins for the paper's four LEAF benchmark tasks,
plus a federated LM token stream for the assigned architectures.

Each generator produces class/cluster structure so that (a) models can
actually learn (loss decreases, validation accuracy rises above chance) and
(b) clients are *heterogeneous* (label-skew + cluster feature transforms),
which is the regime where the paper's K-decay matters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data import partition


@dataclass
class FederatedData:
    """Per-client numpy datasets + a global validation split."""
    client_x: List[np.ndarray]
    client_y: List[np.ndarray]
    val_x: np.ndarray
    val_y: np.ndarray
    num_classes: int

    @property
    def num_clients(self) -> int:
        return len(self.client_x)

    @property
    def weights(self) -> np.ndarray:
        """p_c — fraction of all samples owned by client c (Eq. 1)."""
        n = np.array([len(y) for y in self.client_y], dtype=np.float64)
        return n / n.sum()


def _prototype_classification(rng, num_clients, num_classes, feat_shape,
                              samples_per_client, alpha, noise=0.8,
                              n_val=512, cluster_scale=0.35, num_clusters=8):
    """Gaussian class prototypes + Dirichlet label skew + cluster transforms."""
    dim = int(np.prod(feat_shape))
    protos = rng.normal(size=(num_classes, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    dists = partition.dirichlet_label_skew(rng, num_clients, num_classes, alpha)
    clusters = partition.cluster_assignments(rng, num_clients, num_clusters)
    shifts = rng.normal(size=(num_clusters, dim)).astype(np.float32) * cluster_scale

    cx, cy = [], []
    for c in range(num_clients):
        n = samples_per_client
        y = partition.sample_labels(rng, dists[c], n)
        x = protos[y] + noise * rng.normal(size=(n, dim)).astype(np.float32)
        x = x + shifts[clusters[c]]
        cx.append(x.reshape((n,) + feat_shape).astype(np.float32))
        cy.append(y.astype(np.int32))

    vy = rng.integers(0, num_classes, size=n_val)
    vx = protos[vy] + noise * rng.normal(size=(n_val, dim)).astype(np.float32)
    vx = vx + shifts[rng.integers(0, num_clusters, size=n_val)]  # same mixture
    return FederatedData(cx, cy, vx.reshape((n_val,) + feat_shape).astype(np.float32),
                         vy.astype(np.int32), num_classes)


def make_sent140(rng: np.random.Generator, num_clients=200,
                 samples_per_client=15, vocab=5000) -> FederatedData:
    """Binary sentiment bag-of-words. Positive/negative word buckets per class."""
    pos_words = rng.choice(vocab, size=vocab // 10, replace=False)
    neg_words = rng.choice(vocab, size=vocab // 10, replace=False)
    user_style = rng.dirichlet(np.full(vocab, 0.05), size=num_clients)

    def sample(n, user):
        y = rng.integers(0, 2, size=n)
        x = np.zeros((n, vocab), np.float32)
        for i in range(n):
            words = rng.choice(vocab, size=20, p=user_style[user])
            sentiment = pos_words if y[i] == 1 else neg_words
            words = np.concatenate([words, rng.choice(sentiment, size=8)])
            np.add.at(x[i], words, 1.0)
            x[i] /= max(np.linalg.norm(x[i]), 1e-6)
        return x, y.astype(np.int32)

    cx, cy = [], []
    for c in range(num_clients):
        x, y = sample(samples_per_client, c)
        cx.append(x)
        cy.append(y)
    vx, vy = sample(512, 0)
    return FederatedData(cx, cy, vx, vy, 2)


def make_femnist(rng, num_clients=300, samples_per_client=170,
                 alpha=0.5) -> FederatedData:
    return _prototype_classification(rng, num_clients, 62, (784,),
                                     samples_per_client, alpha)


def make_cifar100(rng, num_clients=100, samples_per_client=100,
                  alpha=0.1) -> FederatedData:
    return _prototype_classification(rng, num_clients, 100, (32, 32, 3),
                                     samples_per_client, alpha, noise=0.5)


def make_shakespeare(rng, num_clients=66, samples_per_client=128, seq_len=80,
                     vocab=79, num_styles=8) -> FederatedData:
    """Markov-chain character streams; each "speaking part" cluster has its
    own transition matrix. x = tokens (S,), y = next tokens (S,)."""
    base = rng.dirichlet(np.full(vocab, 0.3), size=vocab)
    styles = []
    for _ in range(num_styles):
        perturb = rng.dirichlet(np.full(vocab, 0.3), size=vocab)
        styles.append(0.5 * base + 0.5 * perturb)
    clusters = partition.cluster_assignments(rng, num_clients, num_styles)

    def gen(n, T, trans):
        toks = np.zeros((n, T + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=n)
        for t in range(T):
            p = trans[toks[:, t]]
            cum = p.cumsum(axis=1)
            u = rng.random(n)[:, None]
            toks[:, t + 1] = np.minimum((u > cum).sum(axis=1), vocab - 1)
        return toks[:, :-1], toks[:, 1:]

    cx, cy = [], []
    for c in range(num_clients):
        x, y = gen(samples_per_client, seq_len, styles[clusters[c]])
        cx.append(x)
        cy.append(y.astype(np.int32))
    vx, vy = gen(256, seq_len, styles[0])
    return FederatedData(cx, cy, vx, vy.astype(np.int32), vocab)


PAPER_GENERATORS = {
    "sent140": make_sent140,
    "femnist": make_femnist,
    "cifar100": make_cifar100,
    "shakespeare": make_shakespeare,
}


def make_paper_task(name: str, rng: np.random.Generator, *,
                    num_clients: Optional[int] = None,
                    samples_per_client: Optional[int] = None) -> FederatedData:
    kw = {}
    if num_clients is not None:
        kw["num_clients"] = num_clients
    if samples_per_client is not None:
        kw["samples_per_client"] = samples_per_client
    return PAPER_GENERATORS[name](rng, **kw)


# ---------------------------------------------------------------------------
# federated LM tokens (for the assigned transformer architectures)
# ---------------------------------------------------------------------------

def make_lm_clients(rng: np.random.Generator, num_clients: int, vocab: int,
                    seq_len: int, samples_per_client: int = 64,
                    num_styles: int = 8) -> FederatedData:
    """Client-specific unigram-biased token streams (fast to generate)."""
    styles = rng.dirichlet(np.full(vocab, 0.1), size=num_styles)
    clusters = partition.cluster_assignments(rng, num_clients, num_styles)
    cx, cy = [], []
    for c in range(num_clients):
        p = styles[clusters[c]]
        toks = rng.choice(vocab, size=(samples_per_client, seq_len + 1), p=p)
        cx.append(toks[:, :-1].astype(np.int32))
        cy.append(toks[:, 1:].astype(np.int32))
    vt = rng.choice(vocab, size=(64, seq_len + 1), p=styles[0])
    return FederatedData(cx, cy, vt[:, :-1].astype(np.int32),
                         vt[:, 1:].astype(np.int32), vocab)
