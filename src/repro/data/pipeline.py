"""Federated batching: turn per-client datasets into fixed-shape round
tensors consumable by a jitted FedAvg round.

One FedAvg round with N clients and K local steps needs, per client, K
minibatches of size b. We materialise these as stacked arrays of shape
``(N, K, b, *feature)`` — fixed shapes so XLA compiles one round function per
distinct K (K-decay schedules change K across rounds; see the K-quantization
note in DESIGN.md §5).

The round engine consumes *buckets* of consecutive rounds that share one K
(DESIGN.md §6.4); ``bucket_batches`` stacks per-round tensors to
``(B, N, K, b, ...)`` and ``BatchPrefetcher`` builds the next bucket on a
background thread while the current one runs on device (double buffering).

Sampling is with replacement within a client's local dataset (clients own few
samples; the paper's K0*b frequently exceeds n_c too).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.data.synthetic import FederatedData


def sample_clients(rng: np.random.Generator, data: FederatedData,
                   n: int) -> np.ndarray:
    """Uniform client sampling without replacement (Algorithm 1, line 3).

    The historical draw; ``engine.sampling.UniformSampler`` consumes this
    exact stream, and richer policies live behind the ``ClientSampler``
    protocol (DESIGN.md §9.3)."""
    return rng.choice(data.num_clients, size=min(n, data.num_clients),
                      replace=False)


def round_batches(rng: np.random.Generator, data: FederatedData,
                  client_ids: Sequence[int], k: int,
                  batch_size: int) -> Dict[str, np.ndarray]:
    """Build the (N, K, b, ...) tensors for one round."""
    xs, ys = [], []
    for c in client_ids:
        n_c = len(data.client_y[c])
        idx = rng.integers(0, n_c, size=(k, batch_size))
        xs.append(data.client_x[c][idx])
        ys.append(data.client_y[c][idx])
    return {"x": np.stack(xs), "y": np.stack(ys)}


def client_weights(data: FederatedData, client_ids: Sequence[int]) -> np.ndarray:
    """Per-round aggregation weights p_c, renormalised over the round's
    participants (FedAvg, Algorithm 1 line 11 uses the uniform 1/|C_r|;
    weighting by n_c is the Eq. 1-faithful generalisation). A cohort of
    all-empty datasets falls back to uniform weights — a 0/0 here would
    poison the weighted mean (and the params) with NaN."""
    w = np.array([len(data.client_y[c]) for c in client_ids], dtype=np.float64)
    if w.sum() <= 0:
        w = np.ones_like(w)
    return (w / w.sum()).astype(np.float32)


def val_batches(data: FederatedData, batch_size: int) -> List[Dict[str, np.ndarray]]:
    """Full validation split, including the ragged tail batch (< batch_size).

    Evaluators must weight per-batch means by batch size (see
    ``make_eval_fn``) — the tail batch is smaller than the rest.
    """
    n = len(data.val_y)
    out = []
    for i in range(0, n, batch_size):
        out.append({"x": data.val_x[i:i + batch_size],
                    "y": data.val_y[i:i + batch_size]})
    return out


# ---------------------------------------------------------------------------
# bucket construction + background prefetch
# ---------------------------------------------------------------------------

@dataclass
class BucketBatch:
    """Host tensors for one K-bucket of ``n_rounds`` active rounds, padded to
    ``pad_to`` rounds (padding repeats the last active round; the engine
    masks it with ``active=False``)."""
    batches: Dict[str, np.ndarray]   # (B, N, K, b, ...)
    weights: np.ndarray              # (B, N)
    active: np.ndarray               # (B,) bool
    n_rounds: int


def bucket_batches(rng: np.random.Generator, data: FederatedData, *,
                   n_rounds: int, k: int, clients_per_round: int,
                   batch_size: int, pad_to: Optional[int] = None,
                   sampler=None,
                   round_ids: Optional[Sequence[int]] = None) -> BucketBatch:
    """Draws EXACTLY the same rng stream as ``n_rounds`` sequential calls of
    sample_clients + round_batches + client_weights — the engine's bitwise
    parity with the seed per-round loop depends on this ordering.

    ``sampler``: a ``ClientSampler`` deciding participation + aggregation
    weights per round (None = the historical uniform draw, stream-exact);
    ``round_ids``: the absolute 1-based round indices this bucket executes,
    forwarded to round-indexed samplers (e.g. availability masks).

    Gathers sample rows directly into the preallocated ``(B, N, K, b, ...)``
    bucket arrays (``np.take(..., out=...)``): no per-round temporaries, no
    second stacking copy — the bucket build costs less host time than the
    equivalent sequence of per-round ``round_batches`` calls."""
    pad_to = pad_to or n_rounds
    if pad_to < n_rounds:
        raise ValueError(f"pad_to {pad_to} < n_rounds {n_rounds}")
    if round_ids is not None and len(round_ids) < n_rounds:
        raise ValueError(f"{len(round_ids)} round_ids for {n_rounds} rounds")
    n = min(clients_per_round, data.num_clients)
    feat = data.client_x[0].shape[1:]
    lead = (pad_to, n, k, batch_size)
    xs = np.empty(lead + feat, data.client_x[0].dtype)
    ys = np.empty(lead + data.client_y[0].shape[1:], data.client_y[0].dtype)
    weights = np.empty((pad_to, n), np.float32)
    for i in range(n_rounds):
        if sampler is None:
            ids = sample_clients(rng, data, clients_per_round)
            w = client_weights(data, ids)
        else:
            ids, w = sampler.round(
                rng, data, clients_per_round,
                round_ids[i] if round_ids is not None else None)
        for j, c in enumerate(ids):
            n_c = len(data.client_y[c])
            idx = rng.integers(0, n_c, size=k * batch_size)
            np.take(data.client_x[c], idx, axis=0,
                    out=xs[i, j].reshape((k * batch_size,) + feat))
            np.take(data.client_y[c], idx, axis=0,
                    out=ys[i, j].reshape((k * batch_size,)
                                         + data.client_y[0].shape[1:]))
        weights[i] = w
    for i in range(n_rounds, pad_to):     # masked-out padding rounds
        xs[i], ys[i], weights[i] = xs[n_rounds - 1], ys[n_rounds - 1], \
            weights[n_rounds - 1]
    active = np.zeros(pad_to, bool)
    active[:n_rounds] = True
    return BucketBatch(batches={"x": xs, "y": ys}, weights=weights,
                       active=active, n_rounds=n_rounds)


# ---------------------------------------------------------------------------
# streaming cohort slabs (DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclass
class SlabBatch:
    """Host tensors for one C-client slab of a streaming round: clients
    ``[start, stop)`` of the round's cohort. ``weights`` is the slice of the
    GLOBAL round weights (they sum to 1 over the whole cohort, not the
    slab), so slab partial sums compose by plain addition."""
    batches: Dict[str, np.ndarray]   # (C_s, K, b, ...)
    weights: np.ndarray              # (C_s,)
    start: int
    stop: int
    slab: int
    n_slabs: int


def round_slabs(rng: np.random.Generator, data: FederatedData, *, k: int,
                clients_per_round: int, batch_size: int, chunk: int,
                sampler=None, round_id: Optional[int] = None):
    """Yield one round's cohort as ceil(U/C) ``SlabBatch``es of ``chunk``
    clients (the tail slab may be smaller — it compiles once as its own
    shape).

    Draws EXACTLY the same rng stream as the dense single-round
    ``bucket_batches`` build: one sampler/uniform draw up front, then the
    per-client sample indices in cohort order as the slabs stream out.
    That stream identity is what makes chunk == U bitwise-equal to dense
    and dense -> chunked checkpoint resume exact (DESIGN.md §11). Host
    memory is O(chunk) — only the current slab's tensors exist."""
    n = min(clients_per_round, data.num_clients)
    c = min(max(int(chunk), 1), n)
    if sampler is None:
        ids = sample_clients(rng, data, clients_per_round)
        w = client_weights(data, ids)
    else:
        ids, w = sampler.round(rng, data, clients_per_round, round_id)
    feat = data.client_x[ids[0]].shape[1:]
    yfeat = data.client_y[ids[0]].shape[1:]
    xdt, ydt = data.client_x[ids[0]].dtype, data.client_y[ids[0]].dtype
    n_slabs = -(-n // c)
    for s in range(n_slabs):
        start, stop = s * c, min((s + 1) * c, n)
        m = stop - start
        xs = np.empty((m, k, batch_size) + feat, xdt)
        ys = np.empty((m, k, batch_size) + yfeat, ydt)
        for j in range(m):
            cid = ids[start + j]
            n_c = len(data.client_y[cid])
            idx = rng.integers(0, n_c, size=k * batch_size)
            np.take(data.client_x[cid], idx, axis=0,
                    out=xs[j].reshape((k * batch_size,) + feat))
            np.take(data.client_y[cid], idx, axis=0,
                    out=ys[j].reshape((k * batch_size,) + yfeat))
        yield SlabBatch(batches={"x": xs, "y": ys},
                        weights=np.asarray(w[start:stop], np.float32),
                        start=start, stop=stop, slab=s, n_slabs=n_slabs)


class _BuilderBase:
    """submit/get protocol shared by the sync and threaded builders. Requests
    are served strictly FIFO by a single rng, so batch contents depend only
    on (rng state, submission order) — never on timing.

    ``rng`` may be an int seed or a live ``np.random.Generator``; the
    trainer passes its persistent Generator (used in place, not copied) so
    repeated ``run()`` calls continue one sample stream.

    ``place_fn`` (optional): applied to each finished BucketBatch — the
    execution backend's host->device placement (``device_put`` with the
    backend's client sharding). On the threaded builder it runs on the
    worker, so the H2D transfer of bucket r+1 overlaps bucket r's compute.

    ``sampler`` (optional ``ClientSampler``): participation + weight policy
    per round; None keeps the historical uniform draw stream-exactly.
    ``submit(..., rounds=...)`` forwards the bucket's absolute round indices
    to round-indexed samplers.

    ``chunk``/``place_slab_fn``: streaming-cohort mode (DESIGN.md §11) —
    ``submit_slabs(k, round_id)`` enqueues one ROUND whose ceil(U/C) slabs
    come out of ``get()`` one by one, each through ``place_slab_fn`` (the
    backend's client-sharded slab placement). On the threaded builder the
    bounded output queue then double-buffers at slab granularity: the next
    slab's host build + H2D copy overlaps the current slab's compute."""

    def __init__(self, data: FederatedData, clients_per_round: int,
                 batch_size: int,
                 rng: "Union[int, np.random.Generator]",
                 place_fn: Optional[Callable[["BucketBatch"],
                                             "BucketBatch"]] = None,
                 sampler=None, chunk: Optional[int] = None,
                 place_slab_fn: Optional[Callable[["SlabBatch"],
                                                  "SlabBatch"]] = None):
        self.data = data
        self.clients_per_round = clients_per_round
        self.batch_size = batch_size
        self._rng = np.random.default_rng(rng)
        self._place_fn = place_fn
        self._sampler = sampler
        self._chunk = chunk
        self._place_slab_fn = place_slab_fn

    def _build(self, n_rounds: int, k: int, pad_to: Optional[int],
               rounds: Optional[Sequence[int]] = None) -> BucketBatch:
        bb = bucket_batches(self._rng, self.data, n_rounds=n_rounds, k=k,
                            clients_per_round=self.clients_per_round,
                            batch_size=self.batch_size, pad_to=pad_to,
                            sampler=self._sampler, round_ids=rounds)
        return self._place_fn(bb) if self._place_fn is not None else bb

    def _items(self, req):
        """Serve one request as a stream of finished items: a bucket is one
        item; a slab round is ceil(U/C) items. Requests drain strictly in
        submission order off the ONE rng, so the sample stream stays
        deterministic in (rng state, submission order)."""
        if req[0] == "bucket":
            yield self._build(*req[1:])
            return
        _, k, round_id = req
        for sb in round_slabs(self._rng, self.data, k=k,
                              clients_per_round=self.clients_per_round,
                              batch_size=self.batch_size, chunk=self._chunk,
                              sampler=self._sampler, round_id=round_id):
            yield (self._place_slab_fn(sb) if self._place_slab_fn is not None
                   else sb)

    def submit(self, n_rounds: int, k: int, pad_to: Optional[int] = None,
               rounds: Optional[Sequence[int]] = None) -> None:
        raise NotImplementedError

    def submit_slabs(self, k: int, round_id: Optional[int] = None) -> None:
        """Enqueue one streaming round (requires ``chunk``); its slabs come
        out of ``get()`` in order."""
        raise NotImplementedError

    def get(self) -> BucketBatch:
        raise NotImplementedError

    def close(self) -> None:
        pass


class SyncBatchBuilder(_BuilderBase):
    """Builds on ``get`` in the caller's thread (prefetch disabled)."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._pending: List = []
        self._cur = None

    def submit(self, n_rounds, k, pad_to=None, rounds=None):
        self._pending.append(("bucket", n_rounds, k, pad_to, rounds))

    def submit_slabs(self, k, round_id=None):
        self._pending.append(("slabs", k, round_id))

    def get(self):
        while True:
            if self._cur is None:
                self._cur = self._items(self._pending.pop(0))
            try:
                return next(self._cur)
            except StopIteration:
                self._cur = None


class BatchPrefetcher(_BuilderBase):
    """Double-buffered background bucket builder.

    A single daemon thread owns the rng and builds submitted buckets FIFO;
    the bounded output queue (depth 1 by default) means at most one bucket
    is staged ahead — bucket r+1's host tensors are constructed while bucket
    r runs on device. The round scheduler submits the upcoming K-bucket as
    soon as it is known (immediately, for loss-free schedules).
    """

    def __init__(self, data: FederatedData, clients_per_round: int,
                 batch_size: int, rng: "Union[int, np.random.Generator]",
                 depth: int = 1, place_fn=None, sampler=None, chunk=None,
                 place_slab_fn=None):
        super().__init__(data, clients_per_round, batch_size, rng,
                         place_fn=place_fn, sampler=sampler, chunk=chunk,
                         place_slab_fn=place_slab_fn)
        self._req: "queue.Queue" = queue.Queue()
        self._out: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True,
                                        name="fedavg-batch-prefetch")
        self._thread.start()

    def _work(self):
        while True:
            req = self._req.get()
            if req is None:
                return
            it = self._items(req)
            while True:
                try:
                    item = ("ok", next(it))
                except StopIteration:
                    break
                except BaseException as e:      # surfaced on the next get();
                    item = ("err", e)           # worker keeps serving later
                if not self._put(item) or item[0] == "err":
                    break                       # requests

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._out.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def submit(self, n_rounds, k, pad_to=None, rounds=None):
        self._req.put(("bucket", n_rounds, k, pad_to, rounds))

    def submit_slabs(self, k, round_id=None):
        self._req.put(("slabs", k, round_id))

    def get(self):
        status, item = self._out.get()
        if status == "err":
            raise item
        return item

    def close(self):
        self._stop.set()
        self._req.put(None)
        while self._thread.is_alive():
            try:                                 # unblock a pending put
                self._out.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.1)


def make_builder(data: FederatedData, clients_per_round: int, batch_size: int,
                 rng: "Union[int, np.random.Generator]", *,
                 background: bool = True, place_fn=None,
                 sampler=None, chunk=None,
                 place_slab_fn=None) -> _BuilderBase:
    cls = BatchPrefetcher if background else SyncBatchBuilder
    return cls(data, clients_per_round, batch_size, rng, place_fn=place_fn,
               sampler=sampler, chunk=chunk, place_slab_fn=place_slab_fn)
