"""Federated batching: turn per-client datasets into fixed-shape round
tensors consumable by a jitted FedAvg round.

One FedAvg round with N clients and K local steps needs, per client, K
minibatches of size b. We materialise these as stacked arrays of shape
``(N, K, b, *feature)`` — fixed shapes so XLA compiles one round function per
distinct K (K-decay schedules change K across rounds; see the K-quantization
note in DESIGN.md §5).

Sampling is with replacement within a client's local dataset (clients own few
samples; the paper's K0*b frequently exceeds n_c too).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import FederatedData


def sample_clients(rng: np.random.Generator, data: FederatedData,
                   n: int) -> np.ndarray:
    """Uniform client sampling without replacement (Algorithm 1, line 3)."""
    return rng.choice(data.num_clients, size=min(n, data.num_clients),
                      replace=False)


def round_batches(rng: np.random.Generator, data: FederatedData,
                  client_ids: Sequence[int], k: int,
                  batch_size: int) -> Dict[str, np.ndarray]:
    """Build the (N, K, b, ...) tensors for one round."""
    xs, ys = [], []
    for c in client_ids:
        n_c = len(data.client_y[c])
        idx = rng.integers(0, n_c, size=(k, batch_size))
        xs.append(data.client_x[c][idx])
        ys.append(data.client_y[c][idx])
    return {"x": np.stack(xs), "y": np.stack(ys)}


def client_weights(data: FederatedData, client_ids: Sequence[int]) -> np.ndarray:
    """Per-round aggregation weights p_c, renormalised over the round's
    participants (FedAvg, Algorithm 1 line 11 uses the uniform 1/|C_r|;
    weighting by n_c is the Eq. 1-faithful generalisation)."""
    w = np.array([len(data.client_y[c]) for c in client_ids], dtype=np.float64)
    return (w / w.sum()).astype(np.float32)


def val_batches(data: FederatedData, batch_size: int) -> List[Dict[str, np.ndarray]]:
    n = len(data.val_y)
    out = []
    for i in range(0, n - batch_size + 1, batch_size):
        out.append({"x": data.val_x[i:i + batch_size],
                    "y": data.val_y[i:i + batch_size]})
    return out or [{"x": data.val_x, "y": data.val_y}]
