"""Non-IID client partitioners.

Real FL data (LEAF) is unavailable offline; we generate synthetic datasets
with controlled heterogeneity. Two partition mechanisms cover the paper's
tasks:

* ``dirichlet_label_skew`` — per-client class distribution ~ Dir(alpha);
  alpha -> 0 gives one-class clients (max drift), alpha -> inf gives IID.
  (CIFAR100's label-partition in the paper is the alpha->0 extreme.)
* ``cluster_skew`` — clients are grouped into latent "writer/speaker"
  clusters with cluster-specific feature transforms (FEMNIST's
  writer-grouping, Shakespeare's speaking-part grouping).
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_label_skew(rng: np.random.Generator, num_clients: int,
                         num_classes: int, alpha: float) -> np.ndarray:
    """Per-client label distributions, shape (num_clients, num_classes)."""
    return rng.dirichlet(np.full(num_classes, alpha), size=num_clients)


def sample_labels(rng: np.random.Generator, dist: np.ndarray, n: int) -> np.ndarray:
    """Draw n labels from one client's label distribution."""
    return rng.choice(dist.shape[-1], size=n, p=dist)


def cluster_assignments(rng: np.random.Generator, num_clients: int,
                        num_clusters: int) -> np.ndarray:
    return rng.integers(0, num_clusters, size=num_clients)


def heterogeneity_gamma(client_opts: List[float], weights: np.ndarray,
                        global_opt: float) -> float:
    """Paper's Gamma = F* - sum_c p_c f_c*: quantifies non-IID-ness."""
    return float(global_opt - np.sum(weights * np.asarray(client_opts)))
