"""PopulationView — a virtual 10^6+ client-id space over a base dataset.

Population-scale simulation (DESIGN.md §11) needs client ids far beyond
what fits as materialised per-client datasets. ``PopulationView`` presents
``population`` virtual clients over a real ``FederatedData``: virtual id i
resolves to base client ``i % base.num_clients`` lazily at access time, so
the view itself is O(1) state — no list of a million references, no copies.

Only the *sampled cohort* is ever touched (the population samplers draw
O(cohort) ids per round), so batch building, weight computation and
everything downstream stay O(cohort) regardless of the population size.
Unknown attributes (val split, num_classes, ...) delegate to the base
dataset.
"""
from __future__ import annotations

import numpy as np


class _ModView:
    """Lazy ``seq[i % len(seq)]`` sequence of virtual length ``n``."""

    __slots__ = ("_base", "_n")

    def __init__(self, base, n: int):
        self._base = base
        self._n = int(n)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i):
        i = int(i)
        if not -self._n <= i < self._n:
            raise IndexError(f"client id {i} out of range [0, {self._n})")
        return self._base[i % len(self._base)]

    def __iter__(self):
        # O(population) by definition — only here for debugging/small views;
        # the samplers and pipeline never iterate the full population.
        return (self[i] for i in range(self._n))


class PopulationView:
    """``population`` virtual clients over ``base`` (see module docstring).

    Duck-types ``FederatedData``: ``client_x``/``client_y`` are lazy
    modular views, ``num_clients`` is the virtual population, everything
    else delegates to the base dataset."""

    def __init__(self, base, population: int):
        if population < 1:
            raise ValueError(f"population must be >= 1: {population}")
        if base.num_clients < 1:
            raise ValueError("base dataset has no clients")
        self._base = base
        self._population = int(population)
        self.client_x = _ModView(base.client_x, self._population)
        self.client_y = _ModView(base.client_y, self._population)

    @property
    def num_clients(self) -> int:
        return self._population

    @property
    def base(self):
        return self._base

    @property
    def weights(self) -> np.ndarray:
        raise NotImplementedError(
            "PopulationView.weights would materialise an O(population) "
            "array; use pipeline.client_weights over the sampled cohort")

    def __getattr__(self, name):
        return getattr(self._base, name)

    def __repr__(self) -> str:
        return (f"PopulationView(population={self._population}, "
                f"base_clients={self._base.num_clients})")
