"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) every kernel runs with interpret=True — the kernel
body executes as jax ops, which is how correctness is validated offline. On
TPU the same pallas_call lowers to Mosaic. ``INTERPRET`` auto-detects.

Layout adapters live here: the model layers use (B, S, H, hd) attention
tensors while the kernel wants (B, H, S, hd); SSD per-head arrangement and
padding to MXU-aligned shapes also happen here.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import delta_codec as _dc
from repro.kernels import fedavg_reduce as _fr
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ssd_scan as _ssd

INTERPRET = jax.default_backend() != "tpu"

PyTree = Any


# ---------------------------------------------------------------------------
# fedavg aggregation
# ---------------------------------------------------------------------------

def fedavg_reduce(client_stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(N, M) x (N,) -> (M,)."""
    return _fr.fedavg_reduce(client_stack, weights, interpret=INTERPRET)


def fedavg_reduce_tree(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Weighted-average every leaf of a client-stacked param pytree.

    Leaves have a leading client axis: (N, ...) -> (...).
    """
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return fedavg_reduce(flat, weights).reshape(leaf.shape[1:])

    return jax.tree.map(one, client_params)


def fedavg_reduce_sharded(client_stack: jnp.ndarray, weights: jnp.ndarray, *,
                          mesh, client_axes,
                          reduce_tiers=None) -> jnp.ndarray:
    """(N, M) x (N,) -> (M,), N sharded over the mesh client axes: local
    Pallas block-reduce per shard + all-reduce of the f32 partials
    (``reduce_tiers`` selects the hierarchical grouped reduce, §11)."""
    return _fr.fedavg_reduce_sharded(client_stack, weights, mesh=mesh,
                                     client_axes=client_axes,
                                     interpret=INTERPRET,
                                     reduce_tiers=reduce_tiers)


def fedavg_reduce_tree_sharded(client_params: PyTree, weights: jnp.ndarray,
                               *, mesh, client_axes,
                               reduce_tiers=None) -> PyTree:
    """Sharded weighted average of a client-stacked pytree (MeshBackend's
    ``aggregator="kernel"`` path — see DESIGN.md §7)."""
    def one(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return fedavg_reduce_sharded(flat, weights, mesh=mesh,
                                     client_axes=client_axes,
                                     reduce_tiers=reduce_tiers
                                     ).reshape(leaf.shape[1:])

    return jax.tree.map(one, client_params)


# ---------------------------------------------------------------------------
# compressed-delta transport (DESIGN.md §8)
# ---------------------------------------------------------------------------

def int8_delta_reduce(q, w_eff, qr=None, wr_eff=None) -> jnp.ndarray:
    """Fused dequantise + weighted reduce of an int8 client-delta stack:
    q (N, M) int8, w_eff (N,) = weights * per-client scales -> (M,) f32.
    Optional residual plane (two-level codec) fuses into the same pass."""
    return _dc.int8_decompress_reduce(q, w_eff, qr, wr_eff,
                                      interpret=INTERPRET)


def int8_delta_reduce_sharded(q, w_eff, qr=None, wr_eff=None, *, mesh,
                              client_axes, reduce_tiers=None) -> jnp.ndarray:
    """Mesh variant: int8 stack sharded over the client axes, per-shard
    fused decompress-reduce + all-reduce of f32 partials (the
    ``fedavg_reduce_sharded`` contract on compressed payloads)."""
    return _dc.int8_decompress_reduce_sharded(q, w_eff, qr, wr_eff,
                                              mesh=mesh,
                                              client_axes=client_axes,
                                              interpret=INTERPRET,
                                              reduce_tiers=reduce_tiers)


#: Interpret-mode ceiling for the Mosaic one-hot scatter: its dense T x M
#: formulation is what makes the MXU fast on TPU, but in interpret mode
#: (CPU) those are real scalar FLOPs — large payloads fall back to the XLA
#: scatter oracle there. On TPU the Mosaic path is always taken.
MOSAIC_SCATTER_MAX_INTERPRET_WORK = 1 << 20


def mosaic_scatter_ok(payload_entries: int, size: int) -> bool:
    """Whether the one-hot Mosaic formulation is the right scatter for a
    ``payload_entries x size`` dense work volume on this backend."""
    return ((not INTERPRET)
            or payload_entries * size <= MOSAIC_SCATTER_MAX_INTERPRET_WORK)


def topk_delta_reduce(vals, idx, weights, size: int) -> jnp.ndarray:
    """Weighted scatter-add reduction of top-k payloads -> (M,) f32:
    Mosaic one-hot matmul (DESIGN.md §10), XLA scatter as the
    large-payload interpret fallback/oracle."""
    if mosaic_scatter_ok(int(vals.shape[0]) * int(vals.shape[1]), size):
        return _dc.topk_scatter_reduce_mosaic(vals, idx, weights, size,
                                              interpret=INTERPRET)
    return _dc.topk_scatter_reduce(vals, idx, weights, size)


def topk_delta_reduce_sharded(vals, idx, weights, size: int, *, mesh,
                              client_axes, reduce_tiers=None) -> jnp.ndarray:
    """Mesh variant: payload rows sharded over the client axes, per-shard
    one-hot partials + all-reduce (the ``fedavg_reduce_sharded`` contract
    on sparse payloads)."""
    return _dc.topk_scatter_reduce_sharded(vals, idx, weights, size,
                                           mesh=mesh,
                                           client_axes=client_axes,
                                           interpret=INTERPRET,
                                           reduce_tiers=reduce_tiers)


def int8_delta_apply(ref, q, s, qr=None, rs=None) -> jnp.ndarray:
    """Downlink reconstruction: fused dequantise + add-to-ref
    (``ref + q*s [+ qr*rs]``), ref (M,) -> (M,) in ``ref.dtype``."""
    return _dc.int8_decode_apply(ref, q, s, qr, rs, interpret=INTERPRET)


def int8_delta_apply_sharded(ref, q, s, qr=None, rs=None, *, mesh,
                             axes) -> jnp.ndarray:
    """Mesh variant: flat vector sharded over ``axes``, per-shard fused
    decode-apply (elementwise — no collective; DESIGN.md §8.6)."""
    return _dc.int8_decode_apply_sharded(ref, q, s, qr, rs, mesh=mesh,
                                         axes=axes, interpret=INTERPRET)


def topk_delta_apply(ref, vals, idx) -> jnp.ndarray:
    """Downlink top-k reconstruction: scatter-add the kept coordinates into
    a copy of the broadcast reference — Mosaic one-hot matmul with the
    output tile initialised from the reference block; XLA scatter as the
    large-payload interpret fallback/oracle."""
    if mosaic_scatter_ok(int(vals.shape[0]), int(ref.size)):
        return _dc.topk_scatter_apply_mosaic(ref, vals, idx,
                                             interpret=INTERPRET)
    return _dc.topk_scatter_apply(ref, vals, idx)


# ---------------------------------------------------------------------------
# flash attention (model layout adapter)
# ---------------------------------------------------------------------------

def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """Model layout: q (B, Sq, H, hd); k/v (B, Sk, KV, hd) -> (B, Sq, H, hd).

    The attention layer calls this when ``use_kernel=True``. Gradients flow
    through a recompute-based VJP: forward uses the kernel; backward
    differentiates the jnp oracle (flash backward kernels are a recorded
    future optimisation — see DESIGN.md).
    """
    B, Sq, H, hd = q.shape
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = _flash_vjp(qt, kt, vt, causal, window, softcap)
    return jnp.moveaxis(out, 2, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_vjp(q, k, v, causal, window, softcap):
    return _flash_fwd_impl(q, k, v, causal, window, softcap)


def _flash_fwd_impl(q, k, v, causal, window, softcap):
    B, H, Sq, hd = q.shape
    # pad head_dim and sequence dims to MXU-aligned multiples
    qp, pd = _pad_axis(q, 3, 128)
    kp, _ = _pad_axis(k, 3, 128)
    vp, _ = _pad_axis(v, 3, 128)
    qp, pq = _pad_axis(qp, 2, 128)
    kp, pk = _pad_axis(kp, 2, 128)
    vp, _ = _pad_axis(vp, 2, 128)
    # padded key positions must not contribute: causal masking handles query
    # padding; key padding is excluded via an effective window or the causal
    # mask only when Sq == Sk; otherwise mask by shifting scores — we simply
    # require no key padding for non-causal use.
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              softcap=softcap, interpret=INTERPRET,
                              scale=1.0 / (hd ** 0.5))
    if pk and not causal:
        raise ValueError("non-causal flash path requires Sk % 128 == 0")
    return out[:, :, :Sq, :hd]


def _flash_fwd(q, k, v, causal, window, softcap):
    return _flash_fwd_impl(q, k, v, causal, window, softcap), (q, k, v)


def _flash_bwd(causal, window, softcap, res, g):
    q, k, v = res
    from repro.kernels import ref

    def f(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash_vjp.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# SSD scan (model layout adapter)
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, a_log, b, c, d, *, chunk: int = 256):
    """Model layout (matches repro.models.ssm.ssd_chunked):
    x (B, S, H, P); dt (B, S, H); a_log=A (H,) negative rates;
    b/c (B, S, N); d (H,). Returns (y (B,S,H,P), state (B,H,N,P)).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    NC = Sp // chunk
    # rearrange to per-(batch, head)
    xr = jnp.moveaxis(x, 2, 1).reshape(B * H, NC, chunk, P)
    dtr = jnp.moveaxis(dt, 2, 1).reshape(B * H, NC, chunk, 1)
    ar = dtr * jnp.tile(a_log, B)[:, None, None, None]
    br = jnp.broadcast_to(b[:, None], (B, H, Sp, N)).reshape(B * H, NC, chunk, N)
    cr = jnp.broadcast_to(c[:, None], (B, H, Sp, N)).reshape(B * H, NC, chunk, N)
    y, fs = _ssd.ssd_scan(xr.astype(jnp.float32), dtr.astype(jnp.float32),
                          ar.astype(jnp.float32), br.astype(jnp.float32),
                          cr.astype(jnp.float32), interpret=INTERPRET)
    y = jnp.moveaxis(y.reshape(B, H, Sp, P), 1, 2)[:, :S]
    y = y + x[:, :S] * d[None, None, :, None]
    return y.astype(x.dtype), fs.reshape(B, H, N, P)


# ---------------------------------------------------------------------------
# MoE grouped matmul
# ---------------------------------------------------------------------------

def gmm(x, w):
    """(E, C, d) @ (E, d, f) -> (E, C, f), padding C to the 128 tile."""
    E, C, d = x.shape
    xp, pc = _pad_axis(x, 1, 128)
    out = _gmm.gmm(xp, w, interpret=INTERPRET)
    return out[:, :C] if pc else out


def moe_gmm(x, gate, up, down, *, mlp_type: str = "swiglu"):
    """Full gated expert FFN on dispatched tokens: x (E, C, d) -> (E, C, d)."""
    if mlp_type == "swiglu":
        h = jax.nn.silu(gmm(x, gate).astype(jnp.float32))
        h = (h * gmm(x, up).astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(gmm(x, up).astype(jnp.float32),
                        approximate=True).astype(x.dtype)
    return gmm(h, down)
