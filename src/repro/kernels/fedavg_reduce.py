"""Pallas TPU kernel: FedAvg server aggregation  x_bar = sum_c p_c * x_c.

The paper's server op (Algorithm 1, line 11) is a memory-bound weighted
reduction over the client axis. On TPU we tile the (flattened) parameter
vector into VMEM-resident (N x BM) blocks, broadcast the (N,) weight vector
from a VMEM column, and fuse multiply + reduce + cast in one pass — one HBM
read of the client stack, one HBM write of the average, no intermediate
(N, M) f32 tensor.

Block layout:
  x:   (N, M)  -> blocks (N, BM), grid = (M // BM,)
  w:   (N, 1)  -> whole, broadcast within block
  out: (1, M)  -> blocks (1, BM)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _kernel(w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, BM)
    w = w_ref[...].astype(jnp.float32)          # (N, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_reduce(client_stack: jnp.ndarray, weights: jnp.ndarray, *,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = False) -> jnp.ndarray:
    """client_stack: (N, M); weights: (N,) -> (M,)."""
    n, m = client_stack.shape
    pad = (-m) % block
    if pad:
        client_stack = jnp.pad(client_stack, ((0, 0), (0, pad)))
    mp = m + pad
    out = pl.pallas_call(
        _kernel,
        grid=(mp // block,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),      # weights column
            pl.BlockSpec((n, block), lambda i: (0, i)),  # client block
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp), client_stack.dtype),
        interpret=interpret,
    )(weights[:, None], client_stack)
    return out[0, :m]
