"""Pallas TPU kernel: FedAvg server aggregation  x_bar = sum_c p_c * x_c.

The paper's server op (Algorithm 1, line 11) is a memory-bound weighted
reduction over the client axis. On TPU we tile the (flattened) parameter
vector into VMEM-resident (N x BM) blocks, broadcast the (N,) weight vector
from a VMEM column, and fuse multiply + reduce + cast in one pass — one HBM
read of the client stack, one HBM write of the average, no intermediate
(N, M) f32 tensor.

Block layout:
  x:   (N, M)  -> blocks (N, BM), grid = (M // BM,)
  w:   (N, 1)  -> whole, broadcast within block
  out: (1, M)  -> blocks (1, BM)

``fedavg_reduce_sharded`` is the mesh variant (DESIGN.md §7): the client
stack arrives sharded over the mesh client axes, each shard runs the same
block-reduce over its local clients (partial weighted sums in f32), and a
single ``psum`` all-reduces the (M,)-sized partials — the collective moves
one model-size buffer per shard instead of the N-client stack.

``reduce_tiers`` (DESIGN.md §11) splits that single psum into a
*hierarchical* two-tier reduce: e.g. ``(("data",), ("pod",))`` first sums
within each pod's ``data`` sub-axis (the edge aggregation, a grouped
all-reduce local to the pod's interconnect) and then sums the per-pod
partials across pods. The math is identical — psum over disjoint axis
groups composes to the flat psum — but the collective decomposes into
pod-local + cross-pod phases, which is the shape a real edge-aggregation
topology wants. ``psum_tiers`` is the shared helper every sharded reduce
kernel (fedavg / int8 / top-k, ``kernels.delta_codec``) routes through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

DEFAULT_BLOCK = 4096


def psum_tiers(x, axes, reduce_tiers=None):
    """All-reduce ``x`` over ``axes`` — flat (one psum) or hierarchically.

    ``reduce_tiers``: None for the flat single-psum reduce, or a sequence of
    disjoint axis groups whose concatenation covers ``axes`` exactly, e.g.
    ``(("data",), ("pod",))`` for edge-then-cross-pod. Each tier is one
    grouped all-reduce; the composition equals the flat psum bitwise on a
    homogeneous mesh (f32 adds re-associate across tiers — the documented
    ≤1e-6 parity regime on real multi-device meshes)."""
    if reduce_tiers is None:
        return jax.lax.psum(x, tuple(axes))
    tiers = tuple(tuple(t) for t in reduce_tiers)
    flat = tuple(a for t in tiers for a in t)
    if sorted(flat) != sorted(tuple(axes)):
        raise ValueError(f"reduce_tiers {tiers} do not partition client "
                         f"axes {tuple(axes)}")
    for tier in tiers:
        x = jax.lax.psum(x, tier)
    return x


def _kernel(w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, BM)
    w = w_ref[...].astype(jnp.float32)          # (N, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


def _block_reduce(client_stack: jnp.ndarray, weights: jnp.ndarray,
                  block: int, interpret: bool,
                  out_dtype=None) -> jnp.ndarray:
    """The (N, M) x (N,) -> (M,) pallas_call, unjitted (shared by the
    single-device entry point and the per-shard body of the mesh variant)."""
    n, m = client_stack.shape
    pad = (-m) % block
    if pad:
        client_stack = jnp.pad(client_stack, ((0, 0), (0, pad)))
    mp = m + pad
    out = pl.pallas_call(
        _kernel,
        grid=(mp // block,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),      # weights column
            pl.BlockSpec((n, block), lambda i: (0, i)),  # client block
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp),
                                       out_dtype or client_stack.dtype),
        interpret=interpret,
    )(weights[:, None], client_stack)
    return out[0, :m]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_reduce(client_stack: jnp.ndarray, weights: jnp.ndarray, *,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = False) -> jnp.ndarray:
    """client_stack: (N, M); weights: (N,) -> (M,)."""
    return _block_reduce(client_stack, weights, block, interpret)


def fedavg_reduce_sharded(client_stack: jnp.ndarray, weights: jnp.ndarray, *,
                          mesh, client_axes, block: int = DEFAULT_BLOCK,
                          interpret: bool = False,
                          reduce_tiers=None) -> jnp.ndarray:
    """Mesh variant: client_stack (N, M) with N sharded over ``client_axes``.

    Each shard block-reduces its N/shards local clients into an f32 (M,)
    partial, then one all-reduce over the client axes sums the partials;
    the result is replicated (every shard holds the new global params, which
    is exactly what the next round's broadcast wants). N must divide the
    product of the client axes' sizes. ``reduce_tiers`` turns the flat psum
    into the hierarchical grouped reduce (``psum_tiers``, DESIGN.md §11).
    """
    axes = tuple(client_axes)

    def local(x, w):                      # x (N/shards, M); w (N/shards,)
        partial = _block_reduce(x, w, block, interpret,
                                out_dtype=jnp.float32)
        return psum_tiers(partial, axes, reduce_tiers)

    # check_rep=False: shard_map has no replication rule for pallas_call;
    # the psum makes the out_spec P() replication explicit ourselves
    out = shard_map(local, mesh=mesh,
                    in_specs=(P(axes, None), P(axes)),
                    out_specs=P(), check_rep=False)(client_stack, weights)
    return out.astype(client_stack.dtype)
