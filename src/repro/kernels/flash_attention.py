"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Covers every attention variant the assigned archs use: causal, GQA
(kv-head index derived in the BlockSpec index maps), sliding window
(mixtral / gemma2 local layers) and logit softcap (gemma2).

Grid: (B, H, Sq/BQ, Sk/BK) — the key-block axis is innermost and sequential;
running max / denominator / accumulator live in VMEM scratch and persist
across key blocks (the standard TPU flash pattern). Fully-masked key blocks
(beyond the causal frontier or the sliding window) are skipped with pl.when.

Block shapes are MXU-aligned: BQ, BK multiples of 128; head_dim padded to a
multiple of 128 by the wrapper in ops.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    # block-level skip: entire key block after the causal frontier, or
    # entirely left of the sliding window
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kj <= qi)
        if window is not None:
            mask = jnp.logical_and(mask, kj > qi - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)               # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret", "scale"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False,
                    scale: Optional[float] = None):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd).

    ``scale`` defaults to 1/sqrt(hd); callers that pad head_dim must pass
    the scale of the un-padded head_dim.
    """
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
