"""Pallas TPU kernel: grouped matmul for MoE expert FFNs.

After capacity dispatch (repro.models.moe), expert inputs sit in a dense
(E, C, d) tensor; each expert applies its own (d, f) weight. The kernel is a
blocked matmul with the expert index as the outermost grid dim, MXU-aligned
(BC x BD) @ (BD x BF) tiles, and an f32 VMEM accumulator across the d-loop.

Grid: (E, C/BC, f/BF, d/BD) — d innermost/sequential for the accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BC = 128
DEFAULT_BF = 256
DEFAULT_BD = 512


def _fit(dim: int, blk: int) -> int:
    """Largest divisor of dim that is <= blk (halving first, then linear)."""
    blk = min(blk, dim)
    while blk > 1 and dim % blk:
        blk //= 2
    while dim % blk:
        blk -= 1
    return max(blk, 1)


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nd: int):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                  # (BC, BD)
    w = w_ref[0]                                  # (BD, BF)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(idd == nd - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def gmm(x, w, *, bc: int = DEFAULT_BC, bf: int = DEFAULT_BF,
        bd: int = DEFAULT_BD, interpret: bool = False):
    """x: (E, C, d) @ w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    f = w.shape[-1]
    bc = _fit(C, bc)
    bf = _fit(f, bf)
    bd = _fit(d, bd)
    kernel = functools.partial(_kernel, nd=d // bd)
    return pl.pallas_call(
        kernel,
        grid=(E, C // bc, f // bf, d // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
