"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the sequence is
split into Q-length chunks; within a chunk the recurrence is evaluated as
two MXU-friendly matmuls (C B^T masked by the decay kernel L, then applied
to X), and a (N x P) recurrent state carries across chunks in VMEM scratch
— the inter-chunk part is sequential but O(S/Q) steps of tiny matmuls.

Grid: (B*H, S/Q) — chunk axis innermost/sequential, state persists across
it and resets at chunk 0.

Inputs are pre-arranged by the wrapper to per-(batch,head) layout:
  x:  (BH, NC, Q, P)   head inputs
  dt: (BH, NC, Q, 1)   softplus'd step sizes
  a:  (BH, NC, Q, 1)   per-step log-decay = dt * A_h  (precomputed)
  b:  (BH, NC, Q, N)   input projections (group-broadcast)
  c:  (BH, NC, Q, N)   output projections
Outputs: y (BH, NC, Q, P), final state (BH, N, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, state_scr, *,
            q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (Q, 1)
    a = a_ref[0, 0].astype(jnp.float32)           # (Q, 1)  (= dt*A <= 0)
    b = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)           # (Q, N)

    cs = jnp.cumsum(a, axis=0)                    # (Q, 1)

    # intra-chunk: (C B^T) o L, L[i,j] = exp(cs_i - cs_j) for i >= j
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(cs - cs.T)                    # (Q, Q) via broadcast
    gate = jnp.where(ii >= jj, decay, 0.0) * cb   # (Q, Q)
    xdt = x * dt                                  # (Q, P)
    y = jax.lax.dot_general(gate, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y += exp(cs_i) * C_i . S_prev
    state = state_scr[...]                        # (N, P)
    y = y + jnp.exp(cs) * jax.lax.dot_general(
        c, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: S = exp(cs_last) * S_prev + sum_j exp(cs_last - cs_j) dt_j B_j x_j^T
    last = cs[q - 1:q, :]                         # (1, 1)
    sdec = jnp.exp(last - cs)                     # (Q, 1)
    bw = b * (sdec * dt)                          # (Q, N)
    new_state = state * jnp.exp(last) + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_scr[...] = new_state

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        fs_ref[0] = new_state.astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, a, b, c, *, interpret: bool = False):
    """x: (BH, NC, Q, P); dt/a: (BH, NC, Q, 1); b/c: (BH, NC, Q, N).

    Returns (y (BH, NC, Q, P), final_state (BH, N, P)). The D-skip term and
    head/group broadcasting live in the ops.py wrapper.
    """
    BH, NC, Q, P = x.shape
    N = b.shape[-1]
    kernel = functools.partial(_kernel, q=Q, nc=NC)
    y, fs = pl.pallas_call(
        kernel,
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bh, ic: (bh, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bh, ic: (bh, ic, 0, 0)),
            pl.BlockSpec((1, N, P), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, NC, Q, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, fs
