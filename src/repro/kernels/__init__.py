"""Pallas TPU kernels for the compute hot-spots.

<name>.py  — pl.pallas_call + BlockSpec VMEM tiling
ops.py     — public jit'd wrappers + model-layout adapters
ref.py     — pure-jnp oracles (ground truth for the kernel tests)

Validated in interpret=True mode on CPU; the identical pallas_call lowers
to Mosaic on TPU (the deployment target).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
