"""Pallas TPU kernels: fused decompress-reduce for compressed client deltas.

The transport layer (DESIGN.md §8) ships client deltas as quantized
payloads; the server aggregation is then  hat = sum_c w_c * dec(payload_c).
Decoding each client to f32 before reducing would materialise the full
(N, M) f32 stack again — exactly the buffer compression was meant to kill.
These kernels fuse dequantisation into the weighted block-reduce of
``fedavg_reduce``: the int8 payload is the only HBM-resident client stack,
the f32 decode happens per (N x BM) VMEM block, and one (M,) f32 output is
written.

Per-leaf int8 payloads carry a scalar scale per level, so the per-client
dequantise-and-weight factor folds into the weight column:
    sum_c w_c * (q_c * s_c [+ qr_c * rs_c]) = sum_c (w_c s_c) q_c [+ ...]
— i.e. the single-level reduce IS ``fedavg_reduce``'s block-reduce on int8
input with effective weights, and the two-level reduce is one fused kernel
over both int8 planes (one pass, one output write).

``int8_decompress_reduce_sharded`` extends ``fedavg_reduce_sharded``'s mesh
contract: the int8 client stack arrives sharded over the mesh client axes,
each shard decompress-reduces its local clients into an f32 (M,) partial,
and a single ``psum`` sums the partials — the collective moves one f32
model-size buffer per shard while the wire/HBM payload stays int8.

Top-k payloads reduce by scatter-add (``topk_scatter_reduce``): one flat
(N*S,) scatter into an f32 (M,) zero buffer — never an (N, M) dense stack.
The XLA scatter is kept as the oracle; ``topk_scatter_reduce_mosaic`` /
``topk_scatter_apply_mosaic`` are the Mosaic formulation (DESIGN.md §10):
a TPU has no fast random scatter, but the scatter-add is exactly

    out[m] = sum_t contrib[t] * [idx[t] == m]

— a (1, BS) x (BS, BM) matmul against a one-hot matrix built in-register
from an iota compare, accumulated over payload blocks with the output tile
resident in VMEM. Duplicate indices accumulate through the matmul
contraction (scatter-add semantics for free); padded payload slots carry
``idx == -1``, which matches no column. The work is dense T x M, which the
MXU streams far faster than a serialised scatter; ``kernels.ops`` picks the
formulation per call site (XLA scatter stays the oracle and the
interpret-mode fallback for large payloads, where dense T x M work is real
scalar FLOPs). ``topk_scatter_reduce_sharded`` follows
``fedavg_reduce_sharded``'s contract: payloads sharded over the mesh client
axes, per-shard one-hot partials, one psum.

The *downlink* leg (DESIGN.md §8.6) is the mirror image: the server ships
one encoded delta and every client applies it to the broadcast reference.
``int8_decode_apply`` fuses dequantise + add-to-ref in one pass — the int8
payload is read once, the reconstruction ``ref + q*s [+ qr*rs]`` is written
once, and no intermediate f32 delta buffer exists.
``int8_decode_apply_sharded`` follows ``fedavg_reduce_sharded``'s per-shard
kernel contract: the flat parameter vector is sharded over the mesh axes
and each shard decode-applies its local slice; being elementwise (no
contraction over clients), the psum degenerates away and the output keeps
the input sharding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.fedavg_reduce import (DEFAULT_BLOCK, _block_reduce,
                                         psum_tiers)


def _kernel2(w_ref, wr_ref, q_ref, qr_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # (N, BM) primary plane
    qr = qr_ref[...].astype(jnp.float32)          # (N, BM) residual plane
    o_ref[...] = (jnp.sum(q * w_ref[...], axis=0, keepdims=True)
                  + jnp.sum(qr * wr_ref[...], axis=0, keepdims=True))


def _block_reduce2(q, qr, w, wr, block, interpret):
    """Two-plane (N, M) int8 x (N,) f32 -> (M,) f32, one fused pass."""
    n, m = q.shape
    pad = (-m) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        qr = jnp.pad(qr, ((0, 0), (0, pad)))
    mp = m + pad
    out = pl.pallas_call(
        _kernel2,
        grid=(mp // block,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),      # w * scale column
            pl.BlockSpec((n, 1), lambda i: (0, 0)),      # w * rscale column
            pl.BlockSpec((n, block), lambda i: (0, i)),  # primary int8 block
            pl.BlockSpec((n, block), lambda i: (0, i)),  # residual int8 block
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp), jnp.float32),
        interpret=interpret,
    )(w[:, None].astype(jnp.float32), wr[:, None].astype(jnp.float32), q, qr)
    return out[0, :m]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_decompress_reduce(q, w_eff, qr=None, wr_eff=None, *,
                           block: int = DEFAULT_BLOCK,
                           interpret: bool = False) -> jnp.ndarray:
    """q (N, M) int8; w_eff (N,) = weights * per-client scales -> (M,) f32.

    With the optional residual plane ``qr``/``wr_eff`` the two dequantise-
    weight-reduce passes fuse into one kernel invocation per block.
    """
    if qr is None:
        return _block_reduce(q, w_eff.astype(jnp.float32), block, interpret,
                             out_dtype=jnp.float32)
    return _block_reduce2(q, qr, w_eff, wr_eff, block, interpret)


def int8_decompress_reduce_sharded(q, w_eff, qr=None, wr_eff=None, *, mesh,
                                   client_axes, block: int = DEFAULT_BLOCK,
                                   interpret: bool = False,
                                   reduce_tiers=None) -> jnp.ndarray:
    """Mesh variant (extends ``fedavg_reduce_sharded``): the int8 stack is
    sharded over ``client_axes``; per-shard fused decompress-reduce + one
    all-reduce of the f32 (M,) partials (``psum_tiers``: flat or the
    hierarchical grouped reduce). N must divide the axes' size."""
    axes = tuple(client_axes)

    if qr is None:
        def local(x, w):
            partial = _block_reduce(x, w.astype(jnp.float32), block,
                                    interpret, out_dtype=jnp.float32)
            return psum_tiers(partial, axes, reduce_tiers)

        # check_rep=False: no replication rule for pallas_call; the psum
        # makes the P() out_spec replication explicit (as fedavg_reduce)
        return shard_map(local, mesh=mesh,
                         in_specs=(P(axes, None), P(axes)),
                         out_specs=P(), check_rep=False)(q, w_eff)

    def local(x, xr, w, wr):
        partial = _block_reduce2(x, xr, w, wr, block, interpret)
        return psum_tiers(partial, axes, reduce_tiers)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes, None), P(axes), P(axes)),
                     out_specs=P(), check_rep=False)(q, qr, w_eff, wr_eff)


# ---------------------------------------------------------------------------
# downlink: fused decode-apply (DESIGN.md §8.6)
# ---------------------------------------------------------------------------

def _apply_kernel1(s_ref, ref_ref, q_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)             # (1, BM) int8 plane
    o_ref[...] = (ref_ref[...].astype(jnp.float32)
                  + q * s_ref[...]).astype(o_ref.dtype)


def _apply_kernel2(s_ref, rs_ref, ref_ref, q_ref, qr_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    qr = qr_ref[...].astype(jnp.float32)
    o_ref[...] = (ref_ref[...].astype(jnp.float32)
                  + q * s_ref[...] + qr * rs_ref[...]).astype(o_ref.dtype)


def _block_apply(ref, q, s, qr, rs, block, interpret):
    """(M,) ref + int8 payload -> (M,) reconstruction, one fused pass."""
    m = ref.shape[0]
    pad = (-m) % block
    if pad:
        ref = jnp.pad(ref, (0, pad))
        q = jnp.pad(q, (0, pad))
        if qr is not None:
            qr = jnp.pad(qr, (0, pad))
    mp = m + pad
    scol = s.reshape(1, 1).astype(jnp.float32)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    row_spec = pl.BlockSpec((1, block), lambda i: (0, i))
    if qr is None:
        out = pl.pallas_call(
            _apply_kernel1,
            grid=(mp // block,),
            in_specs=[scalar_spec, row_spec, row_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((1, mp), ref.dtype),
            interpret=interpret,
        )(scol, ref[None, :], q[None, :])
    else:
        out = pl.pallas_call(
            _apply_kernel2,
            grid=(mp // block,),
            in_specs=[scalar_spec, scalar_spec, row_spec, row_spec, row_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((1, mp), ref.dtype),
            interpret=interpret,
        )(scol, rs.reshape(1, 1).astype(jnp.float32),
          ref[None, :], q[None, :], qr[None, :])
    return out[0, :m]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def int8_decode_apply(ref, q, s, qr=None, rs=None, *,
                      block: int = DEFAULT_BLOCK,
                      interpret: bool = False) -> jnp.ndarray:
    """ref (M,); q (M,) int8; s scalar scale -> (M,) ``ref + q*s [+ qr*rs]``.

    The downlink reconstruction every client runs: dequantise + add-to-ref
    fused, so the f32 delta is never materialised in HBM. Accumulates in
    f32 and casts back to ``ref.dtype``.
    """
    return _block_apply(ref, q, s, qr, rs, block, interpret)


def int8_decode_apply_sharded(ref, q, s, qr=None, rs=None, *, mesh, axes,
                              block: int = DEFAULT_BLOCK,
                              interpret: bool = False) -> jnp.ndarray:
    """Mesh variant: the flat (M,) vector sharded over ``axes``; each shard
    runs the fused decode-apply on its local slice (scales replicated).
    Elementwise, so unlike the reduce kernels no psum is needed — the
    output keeps the per-shard layout and GSPMD reshards as consumed.
    The axes' size must divide M."""
    axes = tuple(axes)

    if qr is None:
        def local(r, x, sc):
            return _block_apply(r, x, sc, None, None, block, interpret)

        return shard_map(local, mesh=mesh,
                         in_specs=(P(axes), P(axes), P(None)),
                         out_specs=P(axes), check_rep=False)(ref, q, s)

    def local(r, x, sc, xr, rsc):
        return _block_apply(r, x, sc, xr, rsc, block, interpret)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes), P(axes), P(None), P(axes), P(None)),
                     out_specs=P(axes), check_rep=False)(ref, q, s, qr, rs)


def topk_scatter_apply(ref, vals, idx) -> jnp.ndarray:
    """ref (M,); vals/idx (S,) -> ref with the kept coordinates added.

    One flat scatter-add into a copy of the reference — the dense decoded
    delta never exists (same XLA-scatter rationale as the uplink reduce).
    The XLA-scatter oracle for ``topk_scatter_apply_mosaic``."""
    shape = ref.shape
    flat = ref.astype(jnp.float32).reshape(-1)
    out = flat.at[idx].add(vals.astype(jnp.float32))
    return out.reshape(shape).astype(ref.dtype)


def topk_scatter_reduce(vals, idx, weights, size: int) -> jnp.ndarray:
    """vals/idx (N, S), weights (N,) -> (M,) f32 scatter-add reduction.

    One flat (N*S,) scatter into a zeroed (M,) buffer — the decoded dense
    per-client deltas are never materialised. The XLA-scatter oracle for
    ``topk_scatter_reduce_mosaic`` (and the large-payload interpret-mode
    fallback — see ``kernels.ops``).
    """
    contrib = vals.astype(jnp.float32) * weights.astype(jnp.float32)[:, None]
    out = jnp.zeros((size,), jnp.float32)
    return out.at[idx.reshape(-1)].add(contrib.reshape(-1))


# ---------------------------------------------------------------------------
# top-k scatter: Mosaic one-hot-matmul formulation (DESIGN.md §10)
# ---------------------------------------------------------------------------

#: MXU-aligned defaults: BM output columns stay VMEM-resident across the
#: payload-block loop; BS payload entries per one-hot matmul step.
TOPK_BLOCK_M = 512
TOPK_BLOCK_S = 256


def _one_hot_block(idx, block_m, base):
    """(BS,) int32 indices -> (BS, BM) f32 one-hot columns for the output
    tile starting at ``base``. Built from a 2D iota compare (TPU-legal);
    padded slots (idx == -1) match no column."""
    cols = base + jax.lax.broadcasted_iota(jnp.int32,
                                           (idx.shape[0], block_m), 1)
    return (idx[:, None] == cols).astype(jnp.float32)


def _scatter_kernel(idx_ref, c_ref, o_ref, *, block_m):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    oh = _one_hot_block(idx_ref[0, :], block_m, pl.program_id(0) * block_m)
    o_ref[...] += jnp.dot(c_ref[...].astype(jnp.float32), oh,
                          preferred_element_type=jnp.float32)


def _scatter_apply_kernel(ref_ref, idx_ref, c_ref, o_ref, *, block_m):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = ref_ref[...].astype(jnp.float32)

    oh = _one_hot_block(idx_ref[0, :], block_m, pl.program_id(0) * block_m)
    o_ref[...] += jnp.dot(c_ref[...].astype(jnp.float32), oh,
                          preferred_element_type=jnp.float32)


def _pad_flat(x, mult, value=0):
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, (0, pad), constant_values=value) if pad else x


@functools.partial(jax.jit,
                   static_argnames=("size", "block_m", "block_s", "interpret"))
def topk_scatter_reduce_mosaic(vals, idx, weights, size: int, *,
                               block_m: int = TOPK_BLOCK_M,
                               block_s: int = TOPK_BLOCK_S,
                               interpret: bool = False) -> jnp.ndarray:
    """One-hot-matmul ``topk_scatter_reduce``: vals/idx (N, S), weights (N,)
    -> (M,) f32. The per-client weight folds into the payload values before
    flattening, so the kernel reduces one flat (T,) contribution stream;
    grid (M/BM, T/BS) with the output tile innermost-resident."""
    contrib = (vals.astype(jnp.float32)
               * weights.astype(jnp.float32)[:, None]).reshape(-1)
    if size == 0 or contrib.shape[0] == 0:      # empty leaf / k == 0 payload
        return jnp.zeros((size,), jnp.float32)
    c = _pad_flat(contrib, block_s)
    ix = _pad_flat(idx.reshape(-1).astype(jnp.int32), block_s, value=-1)
    mp = size + ((-size) % block_m)
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, block_m=block_m),
        grid=(mp // block_m, c.shape[0] // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),   # idx block
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),   # contrib block
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp), jnp.float32),
        interpret=interpret,
    )(ix[None, :], c[None, :])
    return out[0, :size]


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_s", "interpret"))
def topk_scatter_apply_mosaic(ref, vals, idx, *,
                              block_m: int = TOPK_BLOCK_M,
                              block_s: int = TOPK_BLOCK_S,
                              interpret: bool = False) -> jnp.ndarray:
    """One-hot-matmul ``topk_scatter_apply``: the output tile initialises
    from the reference block instead of zeros, so dequantise + add-to-ref
    stay one fused pass (downlink reconstruction, DESIGN.md §8.6)."""
    shape, dtype = ref.shape, ref.dtype
    flat = ref.astype(jnp.float32).reshape(-1)
    m = flat.shape[0]
    if m == 0 or vals.shape[0] == 0:            # empty leaf / empty payload
        return ref
    r = _pad_flat(flat, block_m)
    c = _pad_flat(vals.astype(jnp.float32).reshape(-1), block_s)
    ix = _pad_flat(idx.reshape(-1).astype(jnp.int32), block_s, value=-1)
    mp = r.shape[0]
    out = pl.pallas_call(
        functools.partial(_scatter_apply_kernel, block_m=block_m),
        grid=(mp // block_m, c.shape[0] // block_s),
        in_specs=[
            pl.BlockSpec((1, block_m), lambda i, j: (0, i)),   # ref tile
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),   # idx block
            pl.BlockSpec((1, block_s), lambda i, j: (0, j)),   # vals block
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, mp), jnp.float32),
        interpret=interpret,
    )(r[None, :], ix[None, :], c[None, :])
    return out[0, :m].reshape(shape).astype(dtype)


def topk_scatter_reduce_sharded(vals, idx, weights, size: int, *, mesh,
                                client_axes,
                                block_m: int = TOPK_BLOCK_M,
                                block_s: int = TOPK_BLOCK_S,
                                interpret: bool = False,
                                reduce_tiers=None) -> jnp.ndarray:
    """Mesh variant (the ``fedavg_reduce_sharded`` contract): payload rows
    sharded over ``client_axes``, each shard one-hot-reduces its local
    clients into an f32 (M,) partial, ``psum_tiers`` sums the partials
    (flat or hierarchically grouped). N must divide the axes' size."""
    axes = tuple(client_axes)

    def local(v, ix, w):
        partial = topk_scatter_reduce_mosaic(
            v, ix, w, size, block_m=block_m, block_s=block_s,
            interpret=interpret)
        # check_rep=False: no replication rule for pallas_call; the psum
        # makes the P() out_spec replication explicit (as fedavg_reduce)
        return psum_tiers(partial, axes, reduce_tiers)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axes, None), P(axes, None), P(axes)),
                     out_specs=P(), check_rep=False)(vals, idx, weights)
