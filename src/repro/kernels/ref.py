"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: each kernel test sweeps shapes and
dtypes and asserts allclose against these functions (kernels run in
interpret=True mode on CPU; on TPU the same pallas_call lowers to Mosaic).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def fedavg_reduce_ref(client_params: jnp.ndarray,
                      weights: jnp.ndarray) -> jnp.ndarray:
    """x: (N, M), w: (N,) -> (M,) = sum_c w_c * x_c (f32 accumulate)."""
    return jnp.einsum("c,cm->m", weights.astype(jnp.float32),
                      client_params.astype(jnp.float32)
                      ).astype(client_params.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd), H = KV * G. -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd)
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window is not None:
        mask = mask & (kj > qi - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, a, b, c, d, *, chunk: int):
    """Mamba2 SSD oracle (delegates to the model's chunked contraction).

    x: (B,S,H,P), dt: (B,S,H), a: (H,) negative rates, b/c: (B,S,N), d: (H,).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                       a.astype(jnp.float32), b.astype(jnp.float32),
                       c.astype(jnp.float32), d.astype(jnp.float32), chunk)


def gmm_ref(x, w) -> jnp.ndarray:
    """Grouped matmul oracle: x (E, C, d) @ w (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def moe_ffn_ref(x, gate, up, down, *, mlp_type: str = "swiglu") -> jnp.ndarray:
    """Full gated expert FFN oracle: x (E, C, d) -> (E, C, d)."""
    if mlp_type == "swiglu":
        h = jax.nn.silu(gmm_ref(x, gate).astype(jnp.float32))
        h = h * gmm_ref(x, up).astype(jnp.float32)
    else:
        h = jax.nn.gelu(gmm_ref(x, up).astype(jnp.float32), approximate=True)
    return gmm_ref(h.astype(x.dtype), down)
