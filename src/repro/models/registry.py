"""Unified model API over all architecture families.

The FedAvg engine, launcher and dry-run all talk to models through:

    init(rng, cfg, dtype)                 -> params
    loss_fn(cfg)(params, batch, **kw)     -> (scalar, metrics)
    init_cache(params, cfg, batch, seq)   -> decode cache
    decode_fn(cfg)(params, cache, token, pos) -> (logits, cache)
    input_specs(cfg, shape, ...)          -> ShapeDtypeStruct stand-ins
    param_count(cfg)                      -> int (no allocation)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, small, transformer

PyTree = Any

# long-context mode: cap on "global" layers' attention span (DESIGN.md §2.5)
LONG_GLOBAL_WINDOW = 32768


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.arch_type == "audio"


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> PyTree:
    if is_encdec(cfg):
        return encdec.init_encdec(rng, cfg, dtype)
    return transformer.init_lm(rng, cfg, dtype)


def loss_fn(cfg: ArchConfig, *, remat: bool = False, moe_path: str = "dispatch",
            use_kernel: bool = False, act_spec=None, attn_kv_spec=None,
            moe_shards=1, moe_spmd_axes=None):
    if is_encdec(cfg):
        def enc_fn(params, batch):
            return encdec.loss_encdec(params, cfg, batch, remat=remat)
        return enc_fn

    def fn(params, batch):
        return transformer.loss_lm(params, cfg, batch, remat=remat,
                                   moe_path=moe_path, use_kernel=use_kernel,
                                   act_spec=act_spec, attn_kv_spec=attn_kv_spec,
                                   moe_shards=moe_shards,
                                   moe_spmd_axes=moe_spmd_axes)
    return fn


def forward_fn(cfg: ArchConfig, *, long_mode: bool = False,
               moe_path: str = "dispatch", use_kernel: bool = False):
    gw = LONG_GLOBAL_WINDOW if long_mode else None
    if is_encdec(cfg):
        def fn(params, batch):
            return encdec.forward_encdec(params, cfg, batch["tokens"],
                                         batch["audio_embeds"])
        return fn

    def fn(params, batch):
        return transformer.forward_lm(params, cfg, batch["tokens"],
                                      batch.get("patch_embeds"),
                                      global_window=gw, moe_path=moe_path,
                                      use_kernel=use_kernel)
    return fn


def init_cache(params, cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.float32, audio_embeds=None, *, ring: bool = False,
               long_mode: bool = False, quant: bool = False):
    if is_encdec(cfg):
        return encdec.init_cache_encdec(params, cfg, audio_embeds, max_seq, dtype)
    gw = LONG_GLOBAL_WINDOW if long_mode else None
    return transformer.init_cache_lm(cfg, batch, max_seq, dtype, ring=ring,
                                     global_window=gw, quant=quant)


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
                enc_batch: Optional[int] = None, *, ring: bool = False,
                long_mode: bool = False, quant: bool = False):
    """ShapeDtypeStruct tree for a decode cache (dry-run, no allocation)."""
    if is_encdec(cfg):
        def fake():
            params = init(jax.random.PRNGKey(0), cfg, dtype)
            audio = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
            return encdec.init_cache_encdec(params, cfg, audio, max_seq, dtype)
        return jax.eval_shape(fake)
    gw = LONG_GLOBAL_WINDOW if long_mode else None
    return jax.eval_shape(
        lambda: transformer.init_cache_lm(cfg, batch, max_seq, dtype,
                                          ring=ring, global_window=gw,
                                          quant=quant))


def decode_fn(cfg: ArchConfig, *, long_mode: bool = False,
              moe_path: str = "dispatch", ring: bool = False):
    gw = LONG_GLOBAL_WINDOW if long_mode else None
    if is_encdec(cfg):
        def fn(params, cache, token, pos):
            return encdec.decode_step_encdec(params, cfg, cache, token, pos)
        return fn

    def fn(params, cache, token, pos):
        return transformer.decode_step_lm(params, cfg, cache, token, pos,
                                          global_window=gw, moe_path=moe_path,
                                          ring=ring)
    return fn


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocates)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, *,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one step of the given kind.

    train/prefill: the full (global_batch, seq) token batch (+ modality stubs).
    decode: one token per sequence (+ position scalar); the KV cache is a
    separate argument supplied by ``cache_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.arch_type == "audio":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "audio_embeds": jax.ShapeDtypeStruct((B, cfg.encoder_seq,
                                                      cfg.d_model), dtype),
            }
        specs = {"tokens": jax.ShapeDtypeStruct((B, S - (cfg.num_patch_tokens
                                                 if cfg.arch_type == "vlm" else 0)),
                                                i32)}
        if cfg.arch_type == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patch_tokens, cfg.d_model), dtype)
        return specs
    # decode: one new token
    return {"token": jax.ShapeDtypeStruct((B,), i32)}


# ---------------------------------------------------------------------------
# parameter counting (runtime model needs |x|)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _param_count_cached(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    leaves = jax.tree.leaves(shapes)
    total = 0
    for leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return int(total)


def param_count(cfg: ArchConfig) -> int:
    return _param_count_cached(cfg)


def active_param_count(cfg: ArchConfig) -> int:
    """MoE: params touched per token (top-k of E experts)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    expert_params = 3 * cfg.d_model * cfg.d_ff * E * cfg.num_layers
    return total - expert_params + expert_params * k // E
