"""Decoder-only LM assembler for the assigned architectures.

Handles arch types: dense, moe, ssm, hybrid, vlm. (audio/enc-dec lives in
``encdec.py``.)

Layers are grouped into *cycles* — one repetition of ``cfg.layer_pattern``
(e.g. (local, global) for gemma2, (5x mamba + shared attn) for zamba2). All
cycles are homogeneous, so their params are stacked on a leading axis and the
forward pass is a ``lax.scan`` over cycles. This keeps HLO size and compile
time flat in depth (96-layer nemotron compiles as one scanned cycle), and is
also what makes per-cycle rematerialisation a one-line policy.

Zamba2's shared attention block (weights shared across all its invocations)
lives outside the stack in ``params['shared']``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers, moe as moe_lib, ssm as ssm_lib

PyTree = Any


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------

def cycle_spec(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.layer_pattern is None:
        return ("mamba",) if cfg.arch_type == "ssm" else ("attn",)
    return tuple(cfg.layer_pattern)


def cycle_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(num full cycles, number of tail layers)."""
    n = len(cycle_spec(cfg))
    return cfg.num_layers // n, cfg.num_layers % n


def _is_shared(cfg: ArchConfig, ltype: str) -> bool:
    return cfg.arch_type == "hybrid" and ltype == "attn"


def _layer_window(cfg: ArchConfig, ltype: str,
                  global_window: Optional[int]) -> Optional[int]:
    if ltype == "local":
        return cfg.sliding_window
    if ltype == "global":
        return global_window           # None normally; capped in long mode
    # plain "attn": honour arch-level SWA (mixtral); full-attention layers
    # (e.g. zamba2's shared block) get the long-mode cap too (DESIGN §2.5)
    if cfg.sliding_window is None:
        return global_window
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# single block init/apply
# ---------------------------------------------------------------------------

def _block_init(rng, cfg: ArchConfig, ltype: str, dtype):
    if ltype == "mamba":
        r1, _ = jax.random.split(rng)
        return {"ln": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
                "ssm": ssm_lib.ssm_init(r1, cfg, dtype)}
    r1, r2 = jax.random.split(rng)
    p = {"ln1": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
         "attn": attention.attn_init(r1, cfg, dtype),
         "ln2": layers.norm_init(cfg.norm_type, cfg.d_model, dtype)}
    if cfg.moe is not None and not _is_shared(cfg, ltype):
        p["moe"] = moe_lib.moe_init(r2, cfg, dtype)
    else:
        d_ff = cfg.d_ff if cfg.d_ff else 4 * cfg.d_model
        p["mlp"] = layers.mlp_init(r2, cfg.d_model, d_ff, cfg.mlp_type, dtype)
    return p


def _block_apply(bp, cfg: ArchConfig, ltype: str, x, positions, *,
                 global_window=None, moe_path="dispatch", use_kernel=False,
                 attn_kv_spec=None, moe_shards=1, moe_spmd_axes=None):
    """Full-sequence block. Returns (x, decode_state_for_this_block)."""
    if ltype == "mamba":
        # NOTE: per-layer jax.checkpoint around the SSD was measured at
        # -2% memory / +12% compute on zamba2 train (EXPERIMENTS §Perf Z1,
        # refuted) — the binding buffers are within a single layer's
        # vectorised-over-chunks backward, which the Pallas ssd_scan kernel
        # (sequential chunk grid, VMEM state) addresses on real TPU.
        h, state = ssm_lib.ssm_forward(bp["ssm"], cfg,
                                       layers.norm_apply(cfg.norm_type, bp["ln"], x))
        return x + h, state
    window = _layer_window(cfg, ltype, global_window)
    h, (k, v) = attention.attention(bp["attn"], cfg,
                                    layers.norm_apply(cfg.norm_type, bp["ln1"], x),
                                    positions, window=window, use_kernel=use_kernel,
                                    kv_spec=attn_kv_spec)
    x = x + h
    hn = layers.norm_apply(cfg.norm_type, bp["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in bp:
        h, aux = moe_lib.moe_apply(bp["moe"], cfg, hn, path=moe_path,
                                   use_kernel=use_kernel, shards=moe_shards,
                                   spmd_axes=moe_spmd_axes)
    else:
        h = layers.mlp_apply(bp["mlp"], hn, cfg.mlp_type)
    return x + h, {"k": k, "v": v, "aux": aux}


def _block_decode(bp, cfg: ArchConfig, ltype: str, x, state, pos, *,
                  global_window=None, moe_path="dense", ring=False):
    if ltype == "mamba":
        h, new_state = ssm_lib.ssm_decode_step(
            bp["ssm"], cfg, layers.norm_apply(cfg.norm_type, bp["ln"], x), state)
        return x + h, new_state
    window = _layer_window(cfg, ltype, global_window)
    use_ring = ring and window is not None
    xn = layers.norm_apply(cfg.norm_type, bp["ln1"], x)
    if "ks" in state:        # int8-quantised cache (beyond-paper Q-KV)
        h, new_state = attention.attention_decode_quant(
            bp["attn"], cfg, xn, state, pos, window=window, ring=use_ring)
    else:
        h, ck, cv = attention.attention_decode(
            bp["attn"], cfg, xn, state["k"], state["v"], pos, window=window,
            ring=use_ring)
        new_state = {"k": ck, "v": cv}
    x = x + h
    hn = layers.norm_apply(cfg.norm_type, bp["ln2"], x)
    if "moe" in bp:
        h, _ = moe_lib.moe_apply(bp["moe"], cfg, hn, path=moe_path)
    else:
        h = layers.mlp_apply(bp["mlp"], hn, cfg.mlp_type)
    return x + h, new_state


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(rng, cfg: ArchConfig, dtype=jnp.float32) -> PyTree:
    spec = cycle_spec(cfg)
    n_cycles, n_tail = cycle_counts(cfg)
    r_embed, r_shared, r_stack, r_tail, r_head = jax.random.split(rng, 5)

    params: Dict[str, Any] = {
        "embed": layers.embedding_init(r_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(r_head, cfg.d_model, cfg.vocab_size,
                                              dtype=dtype)
    if cfg.arch_type == "hybrid":
        params["shared"] = _block_init(r_shared, cfg, "shared_attn_block", dtype)

    def one_cycle(rng):
        ps = {}
        rs = jax.random.split(rng, len(spec))
        for i, lt in enumerate(spec):
            if _is_shared(cfg, lt):
                continue  # weights live in params['shared']
            ps[f"b{i}"] = _block_init(rs[i], cfg, lt, dtype)
        return ps

    if n_cycles > 0:
        params["stack"] = jax.vmap(one_cycle)(jax.random.split(r_stack, n_cycles))
    tail = {}
    rs_tail = jax.random.split(r_tail, max(n_tail, 1))
    for i in range(n_tail):
        lt = spec[i]
        if _is_shared(cfg, lt):
            continue
        tail[f"b{i}"] = _block_init(rs_tail[i], cfg, lt, dtype)
    if n_tail:
        params["tail"] = tail
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _cycle_apply(cparams, shared, cfg, x, positions, **kw):
    spec = cycle_spec(cfg)
    states, aux_total = {}, jnp.zeros((), jnp.float32)
    for i, lt in enumerate(spec):
        bp = shared if _is_shared(cfg, lt) else cparams[f"b{i}"]
        x, st = _block_apply(bp, cfg, lt, x, positions, **kw)
        if isinstance(st, dict) and "aux" in st:
            aux_total = aux_total + st.pop("aux")
        states[f"b{i}"] = st
    return x, states, aux_total


def embed_inputs(params, cfg: ArchConfig, tokens, patch_embeds=None):
    """Token (+ optional patch) embedding. Returns (x, positions, n_prefix)."""
    x = layers.embedding_apply(params["embed"], tokens)
    n_prefix = 0
    if cfg.arch_type == "vlm" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        n_prefix = patch_embeds.shape[1]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions, n_prefix


def forward_lm(params, cfg: ArchConfig, tokens, patch_embeds=None, *,
               global_window: Optional[int] = None, remat: bool = False,
               moe_path: str = "dispatch", use_kernel: bool = False,
               return_states: bool = False, return_features: bool = False,
               act_spec=None, attn_kv_spec=None, moe_shards=1,
               moe_spmd_axes=None):
    """Full-sequence forward. Returns (logits|features, aux[, decode states]).

    ``act_spec``: optional PartitionSpec constraining the residual stream
    between cycles (shrinks remat-saved boundaries on big-d archs).
    ``attn_kv_spec``: optional PartitionSpec for attention k/v (see
    repro.models.attention.attention).
    """
    x, positions, _ = embed_inputs(params, cfg, tokens, patch_embeds)
    kw = dict(global_window=global_window, moe_path=moe_path,
              use_kernel=use_kernel, attn_kv_spec=attn_kv_spec,
              moe_shards=moe_shards, moe_spmd_axes=moe_spmd_axes)
    shared = params.get("shared")

    def constrain(y):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(y, act_spec)
        return y

    x = constrain(x)

    def body(x, cparams):
        y, states, aux = _cycle_apply(cparams, shared, cfg, x, positions, **kw)
        y = constrain(y)
        return y, (states, aux) if return_states else (None, aux)

    if remat:
        body = jax.checkpoint(body)

    stack_states = None
    aux_total = jnp.zeros((), jnp.float32)
    if "stack" in params:
        x, (stack_states, auxs) = jax.lax.scan(body, x, params["stack"])
        aux_total = aux_total + jnp.sum(auxs)
    tail_states = {}
    if "tail" in params:
        spec = cycle_spec(cfg)
        for i in range(cfg.num_layers % len(spec)):
            lt = spec[i]
            bp = shared if _is_shared(cfg, lt) else params["tail"][f"b{i}"]
            x, st = _block_apply(bp, cfg, lt, x, positions, **kw)
            if isinstance(st, dict) and "aux" in st:
                aux_total = aux_total + st.pop("aux")
            tail_states[f"b{i}"] = st

    if return_features:
        if return_states:
            return x, aux_total, {"stack": stack_states, "tail": tail_states}
        return x, aux_total
    logits = _readout(params, cfg, x)
    if return_states:
        return logits, aux_total, {"stack": stack_states, "tail": tail_states}
    return logits, aux_total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def xent_loss(logits, targets, mask=None):
    """Token cross-entropy. logits: (B,S,V); targets: (B,S) int.

    The gold logit is extracted with a one-hot contraction rather than
    ``take_along_axis``: when the vocab dim is sharded over the ``model``
    mesh axis, a gather over the sharded dim makes GSPMD all-gather the
    full logits (19.9 GB for qwen1.5 train_4k — observed in the first
    dry-run); the contraction instead reduces with a tiny psum.
    """
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("...v,...v->...", logits32, onehot)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# Chunk the readout+cross-entropy over sequence positions when the full
# (B, S, V) logits tensor would be large: logits are (re)computed per chunk
# under jax.checkpoint, so neither forward nor backward ever materialises
# them (the f32 logits + one-hot + softmax-bwd block was ~10 GB/chip for
# qwen1.5 train_4k — measured in the dry-run bisection).
LOSS_CHUNK = 512
LOSS_CHUNK_MIN_ELEMENTS = 1 << 28      # B*S*V above this triggers chunking


def _readout(params, cfg: ArchConfig, x):
    x = layers.norm_apply(cfg.norm_type, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.embedding_attend(params["embed"], x)
    else:
        logits = layers.dense_apply(params["lm_head"], x)
    return layers.softcap(logits, cfg.final_logit_softcap)


def _chunked_xent(params, cfg: ArchConfig, feats, targets, mask=None):
    """feats: (B, S, d) pre-readout features; targets: (B, S)."""
    B, S, d = feats.shape
    chunk = LOSS_CHUNK
    pad = (-S) % chunk
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        m0 = mask if mask is not None else jnp.ones((B, S), jnp.float32)
        mask = jnp.pad(m0, ((0, 0), (0, pad)))
        S += pad
    nc = S // chunk
    fc = jnp.moveaxis(feats.reshape(B, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    mc = (jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0) if mask is not None
          else jnp.ones((nc, B, chunk), jnp.float32))

    @jax.checkpoint
    def one(carry, xs):
        f, t, m = xs
        logits = _readout(params, cfg, f)
        logits32 = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits32, axis=-1)
        onehot = jax.nn.one_hot(t, logits.shape[-1], dtype=jnp.float32)
        gold = jnp.einsum("...v,...v->...", logits32, onehot)
        nll_sum, m_sum = carry
        return (nll_sum + jnp.sum((logz - gold) * m), m_sum + jnp.sum(m)), None

    (nll, msum), _ = jax.lax.scan(one, (jnp.zeros(()), jnp.zeros(())),
                                  (fc, tc, mc))
    return nll / jnp.maximum(msum, 1.0)


def loss_lm(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray], *,
            remat: bool = False, moe_path: str = "dispatch",
            use_kernel: bool = False, act_spec=None, attn_kv_spec=None,
            moe_shards=1, moe_spmd_axes=None):
    """Next-token LM loss. batch: {tokens, [patch_embeds], [mask]}."""
    tokens = batch["tokens"]
    patch = batch.get("patch_embeds")
    feats, aux = forward_lm(params, cfg, tokens, patch, remat=remat,
                            moe_path=moe_path, use_kernel=use_kernel,
                            act_spec=act_spec, attn_kv_spec=attn_kv_spec,
                            moe_shards=moe_shards, moe_spmd_axes=moe_spmd_axes,
                            return_features=True)
    n_prefix = patch.shape[1] if (patch is not None and cfg.arch_type == "vlm") else 0
    # predict tokens[t+1] from sequence position (n_prefix + t)
    pred_feats = feats[:, n_prefix:-1] if n_prefix else feats[:, :-1]
    targets = tokens[:, 1:]
    mask = batch.get("mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None else None
    B, Sm1 = targets.shape
    if B * Sm1 * cfg.vocab_size >= LOSS_CHUNK_MIN_ELEMENTS and Sm1 > LOSS_CHUNK:
        loss = _chunked_xent(params, cfg, pred_feats, targets, mask)
    else:
        logits = _readout(params, cfg, pred_feats)
        loss = xent_loss(logits, targets, mask)
    aux_coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    return loss + aux_coef * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def _layer_decode_window(cfg: ArchConfig, ltype: str,
                         global_window=None) -> Optional[int]:
    return _layer_window(cfg, ltype, global_window)


def _block_cache(cfg: ArchConfig, ltype: str, batch: int, max_seq: int, dtype,
                 ring: bool = False, global_window=None, quant: bool = False):
    if ltype == "mamba":
        return ssm_lib.ssm_init_state(cfg, batch, dtype)
    # ring=True (beyond-paper, EXPERIMENTS §Perf R1): windowed layers only
    # allocate a window-length ring buffer instead of the full sequence.
    eff = max_seq
    if ring:
        w = _layer_decode_window(cfg, ltype, global_window)
        if w is not None:
            eff = min(max_seq, w)
    shape = (batch, eff, cfg.num_kv_heads, cfg.head_dim)
    if quant:  # two-level int8 + per-(token, head) f32 scales (§Perf Q-KV)
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "ks": jnp.ones(sshape, jnp.float32),
                "kr": jnp.zeros(shape, jnp.int8),
                "krs": jnp.ones(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "vs": jnp.ones(sshape, jnp.float32),
                "vr": jnp.zeros(shape, jnp.int8),
                "vrs": jnp.ones(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_cache_lm(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.float32,
                  *, ring: bool = False, global_window=None,
                  quant: bool = False):
    spec = cycle_spec(cfg)
    n_cycles, n_tail = cycle_counts(cfg)

    def one_cycle(_):
        return {f"b{i}": _block_cache(cfg, lt, batch, max_seq, dtype,
                                      ring=ring, global_window=global_window,
                                      quant=quant)
                for i, lt in enumerate(spec)}

    cache: Dict[str, Any] = {}
    if n_cycles:
        cache["stack"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_cycles,) + x.shape).copy()
            if hasattr(x, "shape") else x, one_cycle(0))
    if n_tail:
        cache["tail"] = {f"b{i}": _block_cache(cfg, spec[i], batch, max_seq,
                                               dtype, ring=ring,
                                               global_window=global_window,
                                               quant=quant)
                         for i in range(n_tail)}
    return cache


def decode_step_lm(params, cfg: ArchConfig, cache, token, pos, *,
                   global_window: Optional[int] = None,
                   moe_path: str = "dispatch", ring: bool = False):
    """One decode step. token: (B,) int32; pos: scalar int32 position.

    Returns (logits (B,V), new_cache).
    """
    x = layers.embedding_apply(params["embed"], token[:, None])   # (B,1,d)
    spec = cycle_spec(cfg)
    shared = params.get("shared")

    def body(x, scan_in):
        cparams, ccache = scan_in
        new_states = {}
        for i, lt in enumerate(spec):
            bp = shared if _is_shared(cfg, lt) else cparams[f"b{i}"]
            x, st = _block_decode(bp, cfg, lt, x, ccache[f"b{i}"], pos,
                                  global_window=global_window,
                                  moe_path=moe_path, ring=ring)
            new_states[f"b{i}"] = st
        return x, new_states

    new_cache: Dict[str, Any] = {}
    if "stack" in params:
        x, new_cache["stack"] = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    if "tail" in params:
        new_tail = {}
        for i in range(cfg.num_layers % len(spec)):
            lt = spec[i]
            bp = shared if _is_shared(cfg, lt) else params["tail"][f"b{i}"]
            x, st = _block_decode(bp, cfg, lt, x, cache["tail"][f"b{i}"], pos,
                                  global_window=global_window,
                                  moe_path=moe_path, ring=ring)
            new_tail[f"b{i}"] = st
        new_cache["tail"] = new_tail

    logits = _readout(params, cfg, x)
    return logits[:, 0], new_cache
