"""Mixture-of-Experts layer: top-k router + expert FFN bank.

Two execution paths:

* ``dense``   — every expert computes every token, masked-combined. Exact,
  simple, used as the correctness oracle and for reduced smoke configs.
* ``dispatch``— capacity-based sorted dispatch (argsort by expert id ->
  fixed-capacity slots -> grouped expert matmul -> weighted combine).
  FLOP-honest (only top-k experts' compute appears in HLO) and shardable:
  tokens over ``data``, expert bank over ``model`` (expert parallelism).
  This is the production path; ``kernels/moe_gmm`` implements its grouped
  matmul with explicit VMEM tiling.

Aux load-balance loss follows Switch/Mixtral: E * sum_e f_e * P_e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers


def moe_init(rng, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.num_experts
    r = jax.random.split(rng, 4)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"kernel": layers.normal_init(r[0], (d, E), scale, dtype)},
        "gate": layers.normal_init(r[1], (E, d, f), scale, dtype),
        "up": layers.normal_init(r[2], (E, d, f), scale, dtype),
        "down": layers.normal_init(r[3], (E, f, d), 1.0 / math.sqrt(f), dtype),
    }
    return p


def _route(p, cfg: ArchConfig, xf):
    """xf: (T,d) -> (weights (T,k), ids (T,k), aux_loss)."""
    m = cfg.moe
    logits = (xf @ p["router"]["kernel"]).astype(jnp.float32)   # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)                      # (T,k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # load-balance aux: E * sum_e (fraction routed to e) * (mean prob of e)
    E = m.num_experts
    one_hot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)   # top-1 fraction
    f_e = jnp.mean(one_hot, axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return w.astype(xf.dtype), ids, aux


def _expert_ffn(p, cfg: ArchConfig, xe):
    """xe: (E, C, d) -> (E, C, d) through each expert's gated FFN."""
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, p["up"])
    else:  # gelu fallback
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["up"]), approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["down"])


def moe_apply_dense(p, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle path: all experts on all tokens. x: (B,S,d)."""
    B, S, d = x.shape
    m = cfg.moe
    xf = x.reshape(-1, d)
    w, ids, aux = _route(p, cfg, xf)
    outs = _expert_ffn(p, cfg, jnp.broadcast_to(xf, (m.num_experts,) + xf.shape))
    # outs: (E,T,d); combine weighted by routing
    comb = jnp.zeros((xf.shape[0], m.num_experts), x.dtype)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], ids].add(w)
    y = jnp.einsum("te,etd->td", comb, outs)
    return y.reshape(B, S, d), aux


def moe_apply_dispatch(p, cfg: ArchConfig, x, *, use_kernel: bool = False):
    """Production path: capacity-based sorted dispatch. x: (B,S,d)."""
    B, S, d = x.shape
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    w, ids, aux = _route(p, cfg, xf)

    capacity = int(math.ceil(T * k / E * m.capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)                    # pad to 8

    flat_ids = ids.reshape(-1)                                  # (T*k,)
    flat_src = jnp.repeat(jnp.arange(T), k)                     # token index
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    # rank within expert = position - start offset of that expert
    counts = jnp.bincount(sorted_ids, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[sorted_ids]
    keep = rank < capacity
    slot = jnp.where(keep, sorted_ids * capacity + rank, E * capacity)

    # dispatch (extra dummy slot absorbs dropped tokens)
    disp = jnp.zeros((E * capacity + 1, d), x.dtype)
    disp = disp.at[slot].add(xf[flat_src[order]])
    xe = disp[:-1].reshape(E, capacity, d)

    if use_kernel:
        from repro.kernels import ops as kops
        ye = kops.moe_gmm(xe, p["gate"], p["up"], p["down"], mlp_type=cfg.mlp_type)
    else:
        ye = _expert_ffn(p, cfg, xe)

    yf = ye.reshape(E * capacity, d)
    yf = jnp.concatenate([yf, jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = yf[slot] * (flat_w[order] * keep)[:, None]        # (T*k, d)
    y = jnp.zeros((T, d), x.dtype).at[flat_src[order]].add(contrib)
    return y.reshape(B, S, d), aux


def moe_apply_dispatch_sharded(p, cfg: ArchConfig, x, *, shards: int,
                               spmd_axes=None, use_kernel: bool = False):
    """Shard-local dispatch: tokens are split along the sequence into
    ``shards`` groups (one per mesh shard of the token-sharded axis, bound
    via ``spmd_axes``); each group runs capacity dispatch locally, so the
    argsort/scatter buffers stay sharded. GSPMD inserts the expert-weight
    resharding collectives (the expert-parallel all-to-all pattern emerges
    from the einsum against the model-sharded expert banks).
    """
    B, S, d = x.shape
    assert S % shards == 0, (S, shards)
    xs = jnp.moveaxis(x.reshape(B, shards, S // shards, d), 1, 0)

    def local(xl):
        return moe_apply_dispatch(p, cfg, xl, use_kernel=use_kernel)

    ys, auxs = jax.vmap(local, spmd_axis_name=spmd_axes)(xs)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, d), jnp.mean(auxs)


def moe_apply(p, cfg: ArchConfig, x, *, path: str = "dispatch",
              use_kernel: bool = False, shards: int = 1, spmd_axes=None):
    if path == "dense":
        return moe_apply_dense(p, cfg, x)
    if path == "dispatch_sharded" and shards > 1:
        return moe_apply_dispatch_sharded(p, cfg, x, shards=shards,
                                          spmd_axes=spmd_axes,
                                          use_kernel=use_kernel)
    return moe_apply_dispatch(p, cfg, x, use_kernel=use_kernel)
