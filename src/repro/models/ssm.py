"""Mamba2 (SSD — state-space duality) block, pure JAX reference.

Faithful to arXiv:2405.21060: input projection -> short depthwise conv on
(x, B, C) -> per-head scalar-decay SSM evaluated with the chunked SSD
algorithm (intra-chunk quadratic attention-like matmuls + inter-chunk
recurrent state passing) -> gated RMSNorm -> output projection.

The chunked formulation is the TPU adaptation: intra-chunk terms are
MXU-friendly (Q x Q) matmuls; the inter-chunk recurrence is a short
``lax.scan`` over S/Q states. The Pallas ``ssd_scan`` kernel implements the
same contraction with explicit VMEM blocking; this module is its oracle.

n_groups is fixed at 1 (as in the released Mamba2 configs <= 2.7B).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    n_heads = s.n_heads(cfg.d_model)
    conv_dim = d_inner + 2 * s.d_state
    return s, d_inner, n_heads, conv_dim


def ssm_init(rng, cfg: ArchConfig, dtype=jnp.float32):
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    r = jax.random.split(rng, 6)
    in_dim = 2 * d_inner + 2 * s.d_state + n_heads   # z, x, B, C, dt
    p = {
        "in_proj": layers.dense_init(r[0], cfg.d_model, in_dim, dtype=dtype),
        "conv_w": layers.normal_init(r[1], (s.d_conv, conv_dim), 0.1, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        # A in (-exp range); init A in [1, 16] as in the paper's code
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01))).astype(dtype),
        "norm": layers.rmsnorm_init(d_inner, dtype),
        "out_proj": layers.dense_init(r[2], d_inner, cfg.d_model, dtype=dtype),
    }
    return p


def _split_proj(p, cfg: ArchConfig, u):
    """u: (B,S,d_model) -> z, xBC, dt_raw."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    zxbcdt = layers.dense_apply(p["in_proj"], u)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt_raw


def _causal_conv(p, xBC, cfg: ArchConfig):
    """Depthwise causal conv over seq. xBC: (B,S,conv_dim)."""
    s = cfg.ssm
    w = p["conv_w"]                       # (d_conv, conv_dim)
    pad = s.d_conv - 1
    xp = jnp.pad(xBC, ((0, 0), (pad, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(s.d_conv):             # d_conv is tiny (4): unrolled taps
        out = out + xp[:, i:i + xBC.shape[1], :] * w[i]
    return jax.nn.silu(out + p["conv_b"])


def ssd_chunked(x, dt, A, B_, C_, D, chunk: int):
    """Chunked SSD contraction (the oracle for kernels/ssd_scan).

    x:  (B, S, H, P)  per-head inputs
    dt: (B, S, H)     softplus'd step sizes
    A:  (H,)          negative per-head decay rates
    B_: (B, S, N)     input projections (group-broadcast to heads)
    C_: (B, S, N)     output projections
    D:  (H,)          skip
    Returns y: (B, S, H, P), final_state: (B, H, N, P)
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    NC = Sp // chunk
    xc = x.reshape(Bsz, NC, chunk, H, P)
    dtc = dt.reshape(Bsz, NC, chunk, H)
    Bc = B_.reshape(Bsz, NC, chunk, N)
    Cc = C_.reshape(Bsz, NC, chunk, N)

    dA = dtc * A[None, None, None, :]                    # (B,NC,Q,H) <= 0
    cs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    # intra-chunk: Y[i] = sum_{j<=i} C_i.B_j * exp(cs_i - cs_j) * dt_j * x_j
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (B,NC,Q,Q)
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,NC,Qi,Qj,H)
    idx = jnp.arange(chunk)
    mask = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    gate = jnp.where(mask, decay, 0.0) * CB[..., None]   # (B,NC,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", gate, dtc, xc)

    # chunk states: S_c = sum_j exp(cs_last - cs_j) * dt_j * B_j (x) x_j
    last = cs[:, :, -1:, :]                              # (B,NC,1,H)
    sdec = jnp.exp(last - cs)                            # (B,NC,Q,H)
    states = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp", sdec, dtc, Bc, xc)

    # inter-chunk recurrence over NC
    chunk_decay = jnp.exp(last[:, :, 0, :])              # (B,NC,H)

    def step(carry, inp):
        st_prev = carry                                  # (B,H,N,P)
        st_c, dec_c = inp                                # (B,H,N,P), (B,H)
        st_new = st_prev * dec_c[..., None, None] + st_c
        return st_new, st_prev

    init = jnp.zeros((Bsz, H, N, P), x.dtype)
    states_t = jnp.moveaxis(states, 1, 0)                # (NC,B,H,N,P)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)            # (NC,B,H)
    final_state, prev_states = jax.lax.scan(step, init, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,NC,H,N,P)

    # inter-chunk output: C_i . (exp(cs_i) * S_prev)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cs), prev_states)

    y = y_intra + y_inter + xc * D[None, None, None, :, None]
    y = y.reshape(Bsz, Sp, H, P)[:, :S]
    return y, final_state


def ssm_forward(p, cfg: ArchConfig, u) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward. u: (B,S,d_model). Returns (out, final ssm/conv state)."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    Bsz, S, _ = u.shape
    z, xBC_raw, dt_raw = _split_proj(p, cfg, u)
    xBC = _causal_conv(p, xBC_raw, cfg)
    x = xBC[..., :d_inner].reshape(Bsz, S, n_heads, s.head_dim)
    B_ = xBC[..., d_inner:d_inner + s.d_state]
    C_ = xBC[..., d_inner + s.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(x.astype(jnp.float32), dt, A,
                                 B_.astype(jnp.float32), C_.astype(jnp.float32),
                                 p["D"].astype(jnp.float32), s.chunk_size)
    y = y.reshape(Bsz, S, d_inner).astype(u.dtype)
    y = layers.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = layers.dense_apply(p["out_proj"], y)
    # decode-ready states: last (d_conv-1) raw conv inputs + ssm state
    conv_state = xBC_raw[:, -(s.d_conv - 1):, :]
    state = {"ssm": final_state.astype(u.dtype), "conv": conv_state}
    return out, state


def ssm_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode_step(p, cfg: ArchConfig, u, state):
    """One-token recurrent step. u: (B,1,d_model). Returns (out, new_state)."""
    s, d_inner, n_heads, conv_dim = _dims(cfg)
    Bsz = u.shape[0]
    z, xBC_raw, dt_raw = _split_proj(p, cfg, u)       # (B,1,*)
    window = jnp.concatenate([state["conv"], xBC_raw], axis=1)  # (B,d_conv,conv_dim)
    xBC = jnp.einsum("btc,tc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(xBC)                            # (B,conv_dim)
    x = xBC[:, :d_inner].reshape(Bsz, n_heads, s.head_dim)
    B_ = xBC[:, d_inner:d_inner + s.d_state]
    C_ = xBC[:, d_inner + s.d_state:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))      # (H,)
    decay = jnp.exp(dt * A)                           # (B,H)
    st = state["ssm"].astype(jnp.float32)
    st = st * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, B_.astype(jnp.float32), x.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", C_.astype(jnp.float32), st)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, d_inner).astype(u.dtype)
    y = layers.rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = layers.dense_apply(p["out_proj"], y)
    new_state = {"ssm": st.astype(state["ssm"].dtype), "conv": window[:, 1:, :]}
    return out, new_state
