"""Multi-head / grouped-query attention with the variants the assigned archs
need: GQA, QKV bias, sliding-window, logit softcap, RoPE, KV-cache decode,
cross-attention (enc-dec).

The reference path is pure jnp (the oracle); the Pallas flash kernel in
``repro.kernels`` is swapped in via ``use_kernel=True`` for the TPU hot path.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.configs.base import ArchConfig


def attn_init(rng, cfg: ArchConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    rq, rk, rv, ro = jax.random.split(rng, 4)
    return {
        "wq": layers.dense_init(rq, d, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": layers.dense_init(rk, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": layers.dense_init(rv, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": layers.dense_init(ro, cfg.num_heads * hd, d, dtype=dtype),
    }


def _project_qkv(p, cfg: ArchConfig, x, positions, *, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = layers.dense_apply(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = layers.dense_apply(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = layers.dense_apply(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, softcap_val: Optional[float]):
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> (B,KV,G,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) / math.sqrt(hd)
    return layers.softcap(scores.astype(jnp.float32), softcap_val)


def _gqa_combine(probs, v):
    """probs: (B,KV,G,Sq,Sk), v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, KV, G, Sq, Sk = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(probs.dtype))
    return out.reshape(B, Sq, KV * G, v.shape[-1])


def causal_mask(Sq: int, Sk: int, q_offset: int = 0,
                window: Optional[int] = None) -> jnp.ndarray:
    """(Sq, Sk) boolean mask; True = attend. Supports sliding window."""
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Sk)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m


def causal_mask_dyn(Sq: int, Sk: int, q_offset, window: Optional[int] = None):
    """causal_mask with a traced (dynamic) query offset."""
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Sk)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m


# Above this sequence length the reference path processes queries in chunks
# (exact same math — full-row softmax per query — but the (S, S) score buffer
# never materialises; this mirrors the VMEM-blocked Pallas flash kernel and
# keeps the dry-run memory term faithful to the TPU target).
QUERY_CHUNK_THRESHOLD = 2048
QUERY_CHUNK = 1024


def _attend_chunk(q, k, v, softcap_val, mask):
    """q: (B,Qc,H,hd); k/v: (B,Sk,KV,hd); mask: (Qc,Sk) or None."""
    scores = _gqa_scores(q, k, softcap_val)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return _gqa_combine(probs, v)


def attention(p, cfg: ArchConfig, x, positions, *,
              window: Optional[int] = None, use_kernel: bool = False,
              rope: bool = True, kv_spec=None):
    """Full-sequence causal attention (training / prefill). Returns (out, (k, v)).

    ``kv_spec``: optional PartitionSpec for k/v (B, Sk, KV, hd) — sharding
    the key SEQUENCE dim over the model axis keeps attention probabilities
    sharded even when the kv-head count doesn't divide the mesh axis
    (blockwise attention layout; the probs contraction psums over it).
    """
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    if kv_spec is not None:
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)
    B, S = x.shape[:2]
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.attn_logit_softcap)
    elif S > QUERY_CHUNK_THRESHOLD and S % QUERY_CHUNK == 0:
        nc = S // QUERY_CHUNK
        qc = q.reshape(B, nc, QUERY_CHUNK, *q.shape[2:])
        offsets = jnp.arange(nc) * QUERY_CHUNK

        # checkpoint: probs are recomputed in the backward pass instead of
        # being stacked across chunks (flash-attention-style memory profile;
        # the Pallas kernel does the same blocking in VMEM on TPU)
        @jax.checkpoint
        def one(args):
            q_i, off = args
            mask = causal_mask_dyn(QUERY_CHUNK, S, off, window)
            return _attend_chunk(q_i, k, v, cfg.attn_logit_softcap, mask)

        out = jax.lax.map(one, (jnp.moveaxis(qc, 1, 0), offsets))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, *q.shape[2:])
    else:
        mask = causal_mask(S, k.shape[1], window=window)
        out = _attend_chunk(q, k, v, cfg.attn_logit_softcap, mask)
    out = layers.dense_apply(p["wo"], out.reshape(B, S, -1))
    return out, (k, v)


def attention_decode(p, cfg: ArchConfig, x, cache_k, cache_v, pos, *,
                     window: Optional[int] = None, rope: bool = True,
                     ring: bool = False):
    """One-token decode. x: (B,1,d); cache_k/v: (B,Smax|W,KV,hd); pos: scalar.

    ``ring=True`` (windowed archs, beyond-paper serving optimisation): the
    cache holds only the last W tokens as a ring buffer — the new k/v land
    at slot ``pos % W``. Keys are stored post-RoPE (absolute positions), and
    softmax attention is permutation-invariant over keys, so slot order
    never matters; the window mask is the ring itself.

    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    cache_len = cache_k.shape[1]
    slot = pos % cache_len if ring else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    scores = _gqa_scores(q, cache_k, cfg.attn_logit_softcap)    # (B,KV,G,1,L)
    kj = jnp.arange(cache_len)
    valid = kj <= pos       # ring: only un-written slots masked (kj > pos)
    if window is not None and not ring:
        valid = valid & (kj > pos - window)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_combine(probs, cache_v)
    out = layers.dense_apply(p["wo"], out.reshape(B, 1, -1))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# int8-quantised KV cache (beyond-paper serving optimisation Q-KV)
# ---------------------------------------------------------------------------

def quantize_kv(x):
    """x: (..., hd) -> (int8 values, per-vector scale (..., 1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def quantize_kv_residual(x):
    """Two-level int8: primary pass + int8 pass over the primary's residual.

    The residual's dynamic range is one primary quantisation step
    (scale ~ max|x|/127), so the second pass shrinks the worst-case value
    error by another ~127x — enough to keep greedy decode argmax stable
    (single-level int8 was measured flipping top-1 on near-tied logits; see
    tests/test_arch_smoke.py::test_int8_kv_cache_decode_close_to_f32).
    """
    q, scale = quantize_kv(x)
    residual = x.astype(jnp.float32) - q.astype(jnp.float32) * scale
    qr, rscale = quantize_kv(residual)
    return q, scale, qr, rscale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def dequantize_kv_residual(q, scale, qr, rscale, dtype):
    return (dequantize_kv(q, scale, jnp.float32)
            + qr.astype(jnp.float32) * rscale).astype(dtype)


def attention_decode_quant(p, cfg: ArchConfig, x, cache, pos, *,
                           window: Optional[int] = None, rope: bool = True,
                           ring: bool = False):
    """attention_decode against an int8 cache {k,ks,kr,krs,v,vs,vr,vrs}.

    The cache stores two-level int8 values (primary + residual) with
    per-(token, head) f32 scales — HBM reads of the dominant decode buffers
    drop ~2x vs the f32 cache; dequantisation happens in registers/VMEM on
    the fly, and the residual level keeps logits within ~2e-4 of the f32
    path so greedy decode picks identical tokens.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    L = cache["k"].shape[1]
    slot = pos % L if ring else pos
    kq, ks, krq, krs = quantize_kv_residual(k)
    vq, vs, vrq, vrs = quantize_kv_residual(v)
    new = dict(cache)
    for name, val in (("k", kq), ("ks", ks), ("kr", krq), ("krs", krs),
                      ("v", vq), ("vs", vs), ("vr", vrq), ("vrs", vrs)):
        new[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], val.astype(cache[name].dtype), slot, axis=1)
    kd = dequantize_kv_residual(new["k"], new["ks"], new["kr"], new["krs"],
                                x.dtype)
    vd = dequantize_kv_residual(new["v"], new["vs"], new["vr"], new["vrs"],
                                x.dtype)
    scores = _gqa_scores(q, kd, cfg.attn_logit_softcap)
    kj = jnp.arange(L)
    valid = kj <= pos
    if window is not None and not ring:
        valid = valid & (kj > pos - window)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_combine(probs, vd)
    out = layers.dense_apply(p["wo"], out.reshape(B, 1, -1))
    return out, new


def cross_attention_init(rng, cfg: ArchConfig, dtype=jnp.float32):
    return attn_init(rng, cfg, dtype)


def cross_attention(p, cfg: ArchConfig, x, enc_kv: Tuple[jnp.ndarray, jnp.ndarray]):
    """Decoder->encoder cross attention (no mask, no rope).

    x: (B,Sq,d); enc_kv: precomputed (k, v) each (B,Senc,KV,hd).
    """
    B, Sq, _ = x.shape
    hd = cfg.head_dim
    q = layers.dense_apply(p["wq"], x).reshape(B, Sq, cfg.num_heads, hd)
    k, v = enc_kv
    scores = _gqa_scores(q, k, None)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_combine(probs, v)
    return layers.dense_apply(p["wo"], out.reshape(B, Sq, -1))


def cross_attention_kv(p, cfg: ArchConfig, enc_out):
    """Precompute encoder K/V once per sequence (used for all decode steps)."""
    B, Senc, _ = enc_out.shape
    hd = cfg.head_dim
    k = layers.dense_apply(p["wk"], enc_out).reshape(B, Senc, cfg.num_kv_heads, hd)
    v = layers.dense_apply(p["wv"], enc_out).reshape(B, Senc, cfg.num_kv_heads, hd)
    return k, v
