"""Core layer primitives (pure-functional JAX; no flax offline).

Every layer is a pair of functions: ``<name>_init(rng, ...) -> params`` and
``<name>_apply(params, x, ...) -> y``. Params are nested dicts of jnp arrays
so sharding rules can pattern-match on path names.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def normal_init(rng, shape, stddev, dtype):
    return (stddev * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


def lecun_init(rng, shape, fan_in, dtype):
    return normal_init(rng, shape, 1.0 / math.sqrt(max(fan_in, 1)), dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim, out_dim, *, bias=False, dtype=jnp.float32):
    krng, _ = jax.random.split(rng)
    p = {"kernel": lecun_init(krng, (in_dim, out_dim), in_dim, dtype)}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["kernel"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


def norm_init(kind, dim, dtype=jnp.float32):
    return layernorm_init(dim, dtype) if kind == "layernorm" else rmsnorm_init(dim, dtype)


def norm_apply(kind, p, x):
    return layernorm_apply(p, x) if kind == "layernorm" else rmsnorm_apply(p, x)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(rng, vocab, dim, dtype=jnp.float32):
    return {"embedding": normal_init(rng, (vocab, dim), dim ** -0.5, dtype)}


def embedding_apply(p, ids):
    return jnp.take(p["embedding"], ids, axis=0)


def embedding_attend(p, x):
    """Tied-readout logits: x @ E^T."""
    return x @ p["embedding"].T


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]                         # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    half = dim // 2
    div = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model, d_ff, mlp_type, dtype=jnp.float32):
    r1, r2, r3 = jax.random.split(rng, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "gate": dense_init(r1, d_model, d_ff, dtype=dtype),
            "up": dense_init(r2, d_model, d_ff, dtype=dtype),
            "down": dense_init(r3, d_ff, d_model, dtype=dtype),
        }
    # gelu / relu2: plain two-matrix MLP
    return {
        "up": dense_init(r1, d_model, d_ff, dtype=dtype),
        "down": dense_init(r2, d_ff, d_model, dtype=dtype),
    }


def mlp_apply(p, x, mlp_type):
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense_apply(p["gate"], x)) * dense_apply(p["up"], x)
    elif mlp_type == "geglu":
        h = jax.nn.gelu(dense_apply(p["gate"], x), approximate=True) * dense_apply(p["up"], x)
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dense_apply(p["up"], x), approximate=True)
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(dense_apply(p["up"], x)))
    else:
        raise ValueError(f"unknown mlp_type {mlp_type}")
    return dense_apply(p["down"], h)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
