from repro.models import (attention, encdec, layers, moe, registry, small,
                          ssm, transformer)

__all__ = ["attention", "encdec", "layers", "moe", "registry", "small",
           "ssm", "transformer"]
