"""Whisper-style encoder-decoder transformer backbone.

The mel + conv frontend is stubbed per the assignment: the encoder consumes
precomputed frame embeddings of shape (batch, encoder_seq, d_model). We
implement the full transformer backbone: bidirectional encoder self-attention,
causal decoder self-attention, decoder->encoder cross-attention, LayerNorm +
GELU, sinusoidal positions (Whisper uses sinusoidal encoder / learned decoder
positions; we use sinusoidal for both — parameter-free, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, layers

PyTree = Any


def _enc_block_init(rng, cfg: ArchConfig, dtype):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "attn": attention.attn_init(r1, cfg, dtype),
        "ln2": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "mlp": layers.mlp_init(r2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def _dec_block_init(rng, cfg: ArchConfig, dtype):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "ln1": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "self_attn": attention.attn_init(r1, cfg, dtype),
        "ln_x": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "cross_attn": attention.cross_attention_init(r2, cfg, dtype),
        "ln2": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "mlp": layers.mlp_init(r3, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def init_encdec(rng, cfg: ArchConfig, dtype=jnp.float32) -> PyTree:
    r_emb, r_enc, r_dec = jax.random.split(rng, 3)
    enc_rngs = jax.random.split(r_enc, cfg.encoder_layers)
    dec_rngs = jax.random.split(r_dec, cfg.num_layers)
    return {
        "embed": layers.embedding_init(r_emb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_stack": jax.vmap(lambda r: _enc_block_init(r, cfg, dtype))(enc_rngs),
        "enc_norm": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
        "dec_stack": jax.vmap(lambda r: _dec_block_init(r, cfg, dtype))(dec_rngs),
        "final_norm": layers.norm_init(cfg.norm_type, cfg.d_model, dtype),
    }


def encode(params, cfg: ArchConfig, audio_embeds):
    """audio_embeds: (B, S_enc, d) stub frontend output -> encoder states."""
    B, S, _ = audio_embeds.shape
    pos = layers.sinusoidal_positions(S, cfg.d_model).astype(audio_embeds.dtype)
    x = audio_embeds + pos[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    # Encoder attention is bidirectional (attention() is causal) — inline it.
    @jax.checkpoint
    def enc_block(x, bp):
        xn = layers.norm_apply(cfg.norm_type, bp["ln1"], x)
        q, k, v = attention._project_qkv(bp["attn"], cfg, xn, positions, rope=False)
        scores = attention._gqa_scores(q, k, None)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = attention._gqa_combine(probs, v)
        out = layers.dense_apply(bp["attn"]["wo"], out.reshape(B, S, -1))
        x = x + out
        xn = layers.norm_apply(cfg.norm_type, bp["ln2"], x)
        return x + layers.mlp_apply(bp["mlp"], xn, cfg.mlp_type), None

    x, _ = jax.lax.scan(enc_block, x, params["enc_stack"])
    return layers.norm_apply(cfg.norm_type, params["enc_norm"], x)


def forward_encdec(params, cfg: ArchConfig, tokens, audio_embeds, *,
                   remat: bool = False, return_features: bool = False):
    """Training/prefill forward. Returns (logits|features, aux=0)."""
    enc_out = encode(params, cfg, audio_embeds)
    B, S = tokens.shape
    pos = layers.sinusoidal_positions(S, cfg.d_model)
    x = layers.embedding_apply(params["embed"], tokens) + pos[None].astype(
        params["embed"]["embedding"].dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, bp):
        h, _ = attention.attention(
            bp["self_attn"], cfg, layers.norm_apply(cfg.norm_type, bp["ln1"], x),
            positions, rope=False)
        x = x + h
        enc_kv = attention.cross_attention_kv(bp["cross_attn"], cfg, enc_out)
        x = x + attention.cross_attention(
            bp["cross_attn"], cfg, layers.norm_apply(cfg.norm_type, bp["ln_x"], x),
            enc_kv)
        xn = layers.norm_apply(cfg.norm_type, bp["ln2"], x)
        return x + layers.mlp_apply(bp["mlp"], xn, cfg.mlp_type), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_stack"])
    if return_features:
        return x, jnp.zeros((), jnp.float32)
    x = layers.norm_apply(cfg.norm_type, params["final_norm"], x)
    logits = layers.embedding_attend(params["embed"], x)
    return logits, jnp.zeros((), jnp.float32)


def loss_encdec(params, cfg: ArchConfig, batch, *, remat: bool = False):
    # chunked readout+xent (repro.models.transformer._chunked_xent works on
    # this param layout too: tied 'embed' + 'final_norm') — the full f32
    # (B, S, 51865) logits block cost ~45 GB/chip in the first dry-run sweep
    from repro.models import transformer as tr
    feats, aux = forward_encdec(params, cfg, batch["tokens"],
                                batch["audio_embeds"], remat=remat,
                                return_features=True)
    tokens = batch["tokens"]
    mask = batch.get("mask")
    B, S = tokens.shape
    if B * S * cfg.vocab_size >= tr.LOSS_CHUNK_MIN_ELEMENTS and S > tr.LOSS_CHUNK:
        loss = tr._chunked_xent(params, cfg, feats[:, :-1], tokens[:, 1:],
                                mask[:, 1:].astype(jnp.float32)
                                if mask is not None else None)
    else:
        logits = tr._readout(params, cfg, feats)
        loss = tr.xent_loss(logits[:, :-1], tokens[:, 1:], mask)
    return loss, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache_encdec(params, cfg: ArchConfig, audio_embeds, max_seq: int,
                      dtype=jnp.float32):
    """Run the encoder once; precompute per-layer cross K/V; allocate self cache."""
    enc_out = encode(params, cfg, audio_embeds)
    B = enc_out.shape[0]

    def per_layer(bp):
        k, v = attention.cross_attention_kv(bp["cross_attn"], cfg, enc_out)
        return {"xk": k, "xv": v}

    cross = jax.vmap(per_layer)(params["dec_stack"])
    shape = (cfg.num_layers, B, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {"cross": cross,
            "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step_encdec(params, cfg: ArchConfig, cache, token, pos):
    """One decoder token. token: (B,); returns (logits (B,V), new cache)."""
    B = token.shape[0]
    pos_emb = layers.sinusoidal_positions(1, cfg.d_model)  # approx: pos 0 basis
    x = layers.embedding_apply(params["embed"], token[:, None])
    # use true position phase
    full = layers.sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(full, pos, 1, axis=0)[None].astype(x.dtype)

    def body(x, scan_in):
        bp, ck, cv, cross = scan_in
        h, nk, nv = attention.attention_decode(
            bp["self_attn"], cfg, layers.norm_apply(cfg.norm_type, bp["ln1"], x),
            ck, cv, pos, rope=False)
        x = x + h
        x = x + attention.cross_attention(
            bp["cross_attn"], cfg, layers.norm_apply(cfg.norm_type, bp["ln_x"], x),
            (cross["xk"], cross["xv"]))
        xn = layers.norm_apply(cfg.norm_type, bp["ln2"], x)
        return x + layers.mlp_apply(bp["mlp"], xn, cfg.mlp_type), (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["dec_stack"], cache["k"],
                                         cache["v"], cache["cross"]))
    x = layers.norm_apply(cfg.norm_type, params["final_norm"], x)
    logits = layers.embedding_attend(params["embed"], x)
    new_cache = {"cross": cache["cross"], "k": nk, "v": nv}
    return logits[:, 0], new_cache
