"""The paper's four benchmark models (Table 1), pure JAX.

* Sent140:     binary linear classifier on 5k bag-of-words (convex).
* FEMNIST:     200-200 ReLU fully-connected DNN, 62-way softmax.
* CIFAR100:    2x [3x3 conv + 2x2 maxpool] + 512 FC + softmax.
* Shakespeare: 79->8 embedding, 2x stacked GRU(128), softmax.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

PyTree = Any


# ---------------------------------------------------------------------------
# linear (Sent140)
# ---------------------------------------------------------------------------

def linear_init(rng, input_dim: int, num_classes: int, dtype=jnp.float32):
    return {"out": layers.dense_init(rng, input_dim, num_classes, bias=True,
                                     dtype=dtype)}


def linear_apply(params, x):
    return layers.dense_apply(params["out"], x)


# ---------------------------------------------------------------------------
# DNN (FEMNIST): 784 -> 200 -> 200 -> 62
# ---------------------------------------------------------------------------

def dnn_init(rng, input_dim: int, num_classes: int, hidden: int = 200,
             dtype=jnp.float32):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "fc1": layers.dense_init(r1, input_dim, hidden, bias=True, dtype=dtype),
        "fc2": layers.dense_init(r2, hidden, hidden, bias=True, dtype=dtype),
        "out": layers.dense_init(r3, hidden, num_classes, bias=True, dtype=dtype),
    }


def dnn_apply(params, x):
    h = jax.nn.relu(layers.dense_apply(params["fc1"], x))
    h = jax.nn.relu(layers.dense_apply(params["fc2"], h))
    return layers.dense_apply(params["out"], h)


# ---------------------------------------------------------------------------
# CNN (CIFAR100)
# ---------------------------------------------------------------------------

def _conv_init(rng, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    return {"kernel": layers.lecun_init(rng, (kh, kw, cin, cout), fan_in, dtype),
            "bias": jnp.zeros((cout,), dtype)}


def _conv_apply(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["kernel"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["bias"]


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def cnn_init(rng, input_shape: Tuple[int, int, int], num_classes: int,
             channels: Tuple[int, int] = (32, 64), hidden: int = 512,
             dtype=jnp.float32):
    h, w, c = input_shape
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    flat = (h // 4) * (w // 4) * channels[1]
    return {
        "conv1": _conv_init(r1, 3, 3, c, channels[0], dtype),
        "conv2": _conv_init(r2, 3, 3, channels[0], channels[1], dtype),
        "fc": layers.dense_init(r3, flat, hidden, bias=True, dtype=dtype),
        "out": layers.dense_init(r4, hidden, num_classes, bias=True, dtype=dtype),
    }


def cnn_apply(params, x):
    """x: (B, H, W, C)."""
    h = _maxpool2(jax.nn.relu(_conv_apply(params["conv1"], x)))
    h = _maxpool2(jax.nn.relu(_conv_apply(params["conv2"], h)))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(layers.dense_apply(params["fc"], h))
    return layers.dense_apply(params["out"], h)


# ---------------------------------------------------------------------------
# GRU (Shakespeare): emb 8, 2x GRU(128), per-step softmax
# ---------------------------------------------------------------------------

def _gru_cell_init(rng, in_dim, hidden, dtype):
    r1, r2 = jax.random.split(rng)
    scale_x = 1.0 / math.sqrt(in_dim)
    scale_h = 1.0 / math.sqrt(hidden)
    return {
        "wx": layers.normal_init(r1, (in_dim, 3 * hidden), scale_x, dtype),
        "wh": layers.normal_init(r2, (hidden, 3 * hidden), scale_h, dtype),
        "b": jnp.zeros((3 * hidden,), dtype),
    }


def _gru_cell(p, h, x):
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    r, z, n_x = jnp.split(gates, 3, axis=-1)
    # split recurrent contribution for candidate gate per GRU definition
    xg = x @ p["wx"][:, -n_x.shape[-1]:]
    hg = h @ p["wh"][:, -n_x.shape[-1]:]
    r = jax.nn.sigmoid(r)
    z = jax.nn.sigmoid(z)
    n = jnp.tanh(xg + r * hg + p["b"][-n_x.shape[-1]:])
    return (1 - z) * n + z * h


def gru_init(rng, vocab: int, num_classes: int, emb: int = 8,
             hidden: int = 128, dtype=jnp.float32):
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    return {
        "embed": layers.embedding_init(r1, vocab, emb, dtype),
        "gru1": _gru_cell_init(r2, emb, hidden, dtype),
        "gru2": _gru_cell_init(r3, hidden, hidden, dtype),
        "out": layers.dense_init(r4, hidden, num_classes, bias=True, dtype=dtype),
    }


def gru_apply(params, tokens):
    """tokens: (B, S) int32 -> logits (B, S, classes) (next-char prediction)."""
    x = layers.embedding_apply(params["embed"], tokens)   # (B,S,E)
    B, S, E = x.shape
    hidden = params["gru1"]["wh"].shape[0]

    def step(carry, xt):
        h1, h2 = carry
        h1 = _gru_cell(params["gru1"], h1, xt)
        h2 = _gru_cell(params["gru2"], h2, h1)
        return (h1, h2), h2

    h0 = (jnp.zeros((B, hidden), x.dtype), jnp.zeros((B, hidden), x.dtype))
    _, hs = jax.lax.scan(step, h0, jnp.moveaxis(x, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                           # (B,S,H)
    return layers.dense_apply(params["out"], hs)


# ---------------------------------------------------------------------------
# unified task-model API (used by the FedAvg engine)
# ---------------------------------------------------------------------------

def init_task_model(rng, task_cfg, dtype=jnp.float32) -> PyTree:
    m = task_cfg.model
    if m == "linear":
        return linear_init(rng, task_cfg.input_shape[0], task_cfg.num_classes, dtype)
    if m == "dnn":
        return dnn_init(rng, task_cfg.input_shape[0], task_cfg.num_classes, dtype=dtype)
    if m == "cnn":
        return cnn_init(rng, task_cfg.input_shape, task_cfg.num_classes, dtype=dtype)
    if m == "gru":
        return gru_init(rng, task_cfg.num_classes, task_cfg.num_classes, dtype=dtype)
    raise ValueError(m)


def task_loss(params, task_cfg, batch) -> Tuple[jnp.ndarray, Dict]:
    """batch: {'x': features or tokens, 'y': labels}. Mean cross-entropy."""
    m = task_cfg.model
    x, y = batch["x"], batch["y"]
    if m == "linear":
        logits = linear_apply(params, x)
    elif m == "dnn":
        logits = dnn_apply(params, x)
    elif m == "cnn":
        logits = cnn_apply(params, x)
    elif m == "gru":
        logits = gru_apply(params, x)           # (B,S,C); y: (B,S)
    else:
        raise ValueError(m)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), y[..., None],
                               axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, {"acc": acc}
