"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32, MHA in the shared attn block) d_ff=14336
vocab=32000, ssm_state=64.  [arXiv:2411.15242]

Zamba2 interleaves a (shared-weight) full-attention block roughly every 6
Mamba2 blocks; we encode that as a repeating layer pattern. Long-context
serving is supported: SSM state is O(1) and the sparse attention layers'
KV caches are O(L) reads per decoded token.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    mlp_type="swiglu",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=256),
    layer_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "attn"),
    supports_long_context=True,
    source="arXiv:2411.15242",
)
