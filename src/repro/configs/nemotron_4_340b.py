"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP.  [arXiv:2402.16819]

340B params: trained with the client-sequential (Strategy B) FL simulation —
a cross-silo regime where each "client" is a cluster (see DESIGN.md §2.1).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    supports_long_context=False,
    source="arXiv:2402.16819",
)
