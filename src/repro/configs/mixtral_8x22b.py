"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.  [arXiv:2401.04088]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp_type="swiglu",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
    supports_long_context=True,   # SWA bounds the KV cache
    source="arXiv:2401.04088",
)
