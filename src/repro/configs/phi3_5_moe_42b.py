"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    mlp_type="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2),
    supports_long_context=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
