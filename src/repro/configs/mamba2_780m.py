"""mamba2-780m [ssm]: 48L d_model=1536, attention-free, vocab=50280.

SSD (state-space duality), d_state=128, expand=2 (d_inner=3072),
head_dim=64 (48 SSM heads).  [arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    arch_type="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,          # no attention heads; SSM heads live in SSMConfig
    num_kv_heads=1,
    d_ff=0,               # Mamba2 blocks have no separate MLP
    vocab_size=50280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    supports_long_context=True,
    source="arXiv:2405.21060",
)
