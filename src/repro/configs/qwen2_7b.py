"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA, QKV bias, SwiGLU, RoPE theta=1e6.  [arXiv:2407.10671]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mlp_type="swiglu",
    rope_theta=1e6,
    supports_long_context=False,
    source="arXiv:2407.10671",
)
