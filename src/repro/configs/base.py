"""Config dataclasses for the repro framework.

Every assigned architecture gets an ``ArchConfig`` (exact published spec) plus
a ``reduced()`` variant used by CPU smoke tests (2 layers, d_model<=512,
<=4 experts). ``ShapeConfig`` describes the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for dense (einsum) dispatch path
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyper-params."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    """Transformer-family architecture description.

    ``arch_type`` in {dense, moe, ssm, hybrid, audio, vlm}. ``layer_types``
    optionally gives a per-layer pattern (e.g. mamba/attn for hybrids,
    local/global for gemma2); if None, all layers are the same.
    """
    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    # attention variants
    qkv_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None     # SWA window (tokens)
    layer_pattern: Optional[Tuple[str, ...]] = None  # cycled over layers
    rope_theta: float = 10000.0
    # MLP variants: 'swiglu' | 'gelu' | 'relu2' (squared relu) | 'geglu'
    mlp_type: str = "swiglu"
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # state-space
    ssm: Optional[SSMConfig] = None
    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_seq: int = 0                     # stub frontend output length
    # vlm
    num_patch_tokens: int = 0                # stub vision tokens per sample
    norm_type: str = "rmsnorm"               # 'rmsnorm' | 'layernorm'
    tie_embeddings: bool = False
    # which input shapes this arch supports (see DESIGN.md §2.5)
    supports_long_context: bool = False
    source: str = ""                         # citation

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def layer_types(self) -> Tuple[str, ...]:
        if self.layer_pattern is None:
            base = ("mamba",) if self.arch_type == "ssm" else ("attn",)
            return tuple(base * self.num_layers)[: self.num_layers]
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        d_model = min(self.d_model, 128)
        num_heads = min(self.num_heads, 4)
        head_dim = max(d_model // num_heads, 16)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep GQA ratio flavour: if original had kv<heads, use kv=heads//2
        if self.num_kv_heads < self.num_heads:
            num_kv = max(1, num_heads // 2)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, num_experts=min(4, self.moe.num_experts),
                                      top_k=min(2, self.moe.top_k))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=32, chunk_size=32)
        pattern = self.layer_pattern
        if pattern is not None:
            pattern = tuple(pattern[:2]) if len(pattern) >= 2 else pattern
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 4 * d_model) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            layer_pattern=pattern,
            moe=moe,
            ssm=ssm,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            num_patch_tokens=min(self.num_patch_tokens, 16) if self.num_patch_tokens else 0,
        )

    # -- parameter counting (used by the runtime model: |x| in Eq. 3) -----
    def param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self)

    def model_size_megabits(self, bytes_per_param: int = 4) -> float:
        return self.param_count() * bytes_per_param * 8 / 1e6


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


@dataclass(frozen=True)
class FedConfig:
    """FedAvg algorithm + schedule configuration (the paper's knobs)."""
    total_clients: int = 100
    clients_per_round: int = 16
    rounds: int = 100
    k0: int = 16                      # K_0 — initial local steps
    eta0: float = 0.1                 # η_0 — client learning rate
    batch_size: int = 32
    k_schedule: str = "fixed"         # fixed|rounds|error|step|cosine
    eta_schedule: str = "fixed"       # fixed|rounds|error|step
    loss_window: int = 100            # s in Eq. 15
    plateau_patience: int = 50        # rounds of no val improvement => step decay
    step_decay_factor: float = 10.0   # K0/10 per the paper
    k_min: int = 1
    k_quantize: bool = False          # beyond-paper: quantize K to geometric grid
    k_grid0: Optional[int] = None     # explicit quantize_k grid anchor (None =
                                      # k0). Sweeps pin one anchor across
                                      # points so differing k0 values share
                                      # bucket shapes/executables (§12)
    server_optimizer: str = "avg"     # avg | fedadam | fedavgm | fedyogi
    server_lr: float = 1.0
    seed: int = 0
    strategy: str = "parallel"        # parallel (vmap) | sequential (scan)
    # --- round engine (DESIGN.md §6) ---
    aggregator: str = "mean"          # mean | kernel | median | trimmed_mean
    trim_fraction: float = 0.1        # for aggregator="trimmed_mean"
    # --- delta transport (DESIGN.md §8) ---
    transport: str = "none"           # none | int8 | int8x2 | topk
    topk_frac: float = 0.1            # kept fraction for transport="topk"
    downlink: str = "none"            # server broadcast codec (same names
                                      # plus "adaptive"; DESIGN.md §8.6/§10)
    downlink_ref: str = "f32"         # server-held ref/residual store:
                                      # f32 | q8 (DESIGN.md §10.3)
    # --- client sampling (DESIGN.md §9.3) ---
    sampler: str = "uniform"          # uniform | weighted | fixed_cohort
                                      # | availability | population
    cohort: Optional[Tuple[int, ...]] = None   # fixed_cohort membership
                                      # (None = clients 0..n-1)
    availability: float = 0.9         # per-round online prob (availability);
                                      # diurnal peak prob (population)
    population: int = 0               # population sampler: virtual client-id
                                      # space (0 = total_clients)
    day_rounds: int = 24              # population: diurnal period in rounds
    base_availability: float = 0.05   # population: diurnal trough prob
    bucket_rounds: int = 8            # max rounds per jitted K-bucket scan
    feedback_bucket_rounds: int = 1   # bucket length for error/step schedules
                                      # (1 == per-round feedback, seed-exact)
    prefetch: bool = True             # build bucket r+1 on a background thread
    # --- streaming cohorts (DESIGN.md §11) ---
    cohort_chunk: Optional[int] = None  # slab size C: run the round's U
                                      # clients in ceil(U/C) streaming slabs
                                      # (None = dense vmapped cohort)
    # --- async buffered aggregation (DESIGN.md §13) ---
    aggregation: str = "sync"         # sync | async (FedBuff-style)
    buffer_size: Optional[int] = None  # async: apply the buffer after this
                                      # many arrivals (None = cohort size)
    staleness_weight: str = "constant"  # async: constant | inv | poly
    max_staleness: Optional[int] = None  # async: drop arrivals staler than
                                      # this many versions (None = keep all)


@dataclass(frozen=True)
class RuntimeModelConfig:
    """Paper §3.2 / §4.2 constants (Eq. 3-5)."""
    download_mbps: float = 20.0   # D, 4G LTE UK
    upload_mbps: float = 5.0      # U
    beta_seconds: float = 0.1     # per-minibatch client compute time
    bytes_per_param: int = 4
