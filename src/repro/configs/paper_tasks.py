"""The paper's four benchmark FL tasks (Table 1) as configs.

Offline container => datasets are synthetic generators with the same shapes,
client counts and non-IID structure (see repro/data/synthetic.py). The
runtime-model constants (model size |x|, beta from Table 2, D/U bandwidths)
are the paper's own numbers, so the wall-clock / compute-cost results
reproduce exactly.
"""
from dataclasses import dataclass
from typing import Tuple

from repro.configs.base import FedConfig, RuntimeModelConfig


@dataclass(frozen=True)
class PaperTaskConfig:
    name: str
    model: str                 # 'linear' | 'dnn' | 'cnn' | 'gru'
    num_classes: int
    input_shape: Tuple[int, ...]
    fed: FedConfig
    runtime: RuntimeModelConfig
    model_size_mb: float       # megabits, Table 1
    val_fraction: float = 0.2


# Table 1 + Table 2 values.
SENT140 = PaperTaskConfig(
    name="sent140",
    model="linear",
    num_classes=2,
    input_shape=(5000,),              # bag-of-words, 5k vocab
    fed=FedConfig(total_clients=21876, clients_per_round=50, rounds=10000,
                  k0=60, eta0=3.0, batch_size=8),
    runtime=RuntimeModelConfig(beta_seconds=5.2e-3),
    model_size_mb=0.32,
)

FEMNIST = PaperTaskConfig(
    name="femnist",
    model="dnn",
    num_classes=62,
    input_shape=(784,),               # 28x28 flattened greyscale
    fed=FedConfig(total_clients=3000, clients_per_round=60, rounds=10000,
                  k0=80, eta0=0.3, batch_size=32),
    runtime=RuntimeModelConfig(beta_seconds=0.017),
    model_size_mb=6.71,
)

CIFAR100 = PaperTaskConfig(
    name="cifar100",
    model="cnn",
    num_classes=100,
    input_shape=(32, 32, 3),
    fed=FedConfig(total_clients=500, clients_per_round=25, rounds=10000,
                  k0=50, eta0=0.01, batch_size=32),
    runtime=RuntimeModelConfig(beta_seconds=0.31),
    model_size_mb=40.0,
)

SHAKESPEARE = PaperTaskConfig(
    name="shakespeare",
    model="gru",
    num_classes=79,
    input_shape=(80,),                # sequence length 80
    fed=FedConfig(total_clients=660, clients_per_round=10, rounds=10000,
                  k0=80, eta0=0.1, batch_size=32),
    runtime=RuntimeModelConfig(beta_seconds=1.5),
    model_size_mb=5.21,
)

PAPER_TASKS = {t.name: t for t in (SENT140, FEMNIST, CIFAR100, SHAKESPEARE)}


def get_paper_task(name: str) -> PaperTaskConfig:
    return PAPER_TASKS[name]
