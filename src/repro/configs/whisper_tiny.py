"""whisper-tiny [audio]: enc-dec, 4+4L d_model=384 6H (kv=6) d_ff=1536
vocab=51865.  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor frontend is a STUB —
``input_specs()`` provides precomputed (batch, 1500, 384) frame embeddings.
We implement the encoder/decoder transformer backbone (LayerNorm + GELU,
learned positions, cross-attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,             # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    encoder_layers=4,
    encoder_seq=1500,
    tie_embeddings=True,
    supports_long_context=False,
    source="arXiv:2212.04356",
)
