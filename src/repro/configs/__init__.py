"""Config registry: ``get_arch('<id>')`` resolves an assigned architecture.

Arch ids use the exact assigned names (dots and dashes); module names use
underscores.
"""
from repro.configs.base import (ArchConfig, FedConfig, MoEConfig,
                                RuntimeModelConfig, ShapeConfig, SSMConfig)
from repro.configs.shapes import SHAPES, get_shape
from repro.configs.paper_tasks import PAPER_TASKS, get_paper_task

from repro.configs import (gemma2_27b, llava_next_34b, mamba2_780m,
                           mixtral_8x22b, nemotron_4_340b, phi3_5_moe_42b,
                           qwen1_5_0_5b, qwen2_7b, whisper_tiny, zamba2_7b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (zamba2_7b, qwen1_5_0_5b, mamba2_780m, qwen2_7b, phi3_5_moe_42b,
              gemma2_27b, whisper_tiny, mixtral_8x22b, nemotron_4_340b,
              llava_next_34b)
}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return ARCHS[name[: -len("-reduced")]].reduced()
    return ARCHS[name]


__all__ = [
    "ArchConfig", "FedConfig", "MoEConfig", "RuntimeModelConfig",
    "ShapeConfig", "SSMConfig", "ARCHS", "SHAPES", "PAPER_TASKS",
    "get_arch", "get_shape", "get_paper_task",
]
