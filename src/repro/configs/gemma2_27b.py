"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.

Local(SWA-4096)/global alternating layers, attention logit softcap 50,
final logit softcap 30, GeGLU MLP, head_dim=128.  [arXiv:2408.00118]

Long-context serving (500k) runs in a documented deviation mode where the
"global" layers' attention span is capped (see DESIGN.md §2.5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    arch_type="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mlp_type="geglu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    norm_type="rmsnorm",
    tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2408.00118",
)
