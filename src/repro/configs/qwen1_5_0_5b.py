"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.

QKV bias, SwiGLU MLP, RoPE.  [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    mlp_type="swiglu",
    tie_embeddings=True,
    supports_long_context=False,
    source="hf:Qwen/Qwen1.5-0.5B",
)
