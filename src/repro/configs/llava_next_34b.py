"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT vision encoder + projector is a STUB — ``input_specs()`` provides
(batch, num_patch_tokens, d_model) anyres patch embeddings which the language
backbone consumes interleaved with text token embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_type="swiglu",
    num_patch_tokens=576,     # one anyres base tile of 24x24 patches
    supports_long_context=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
