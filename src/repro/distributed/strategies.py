"""Mesh-level FedAvg train steps and serving steps for the assigned archs.

The federated strategies are a thin shim over the engine's execution
backends (``repro.core.engine.backends``, DESIGN.md §7): ``make_fed_train_step``
builds the arch's loss function (remat / MoE path / activation-sharding
plumbing) and delegates the round itself — K-step local SGD, aggregation,
server step — to a ``MeshBackend`` round core, the same code the
K-bucketed ``RoundEngine`` executes. The two geometries it exposes:

Strategy A — ``parallel`` (cross-device FL): the round's N clients live on
the mesh ``data`` (x ``pod``) axes via ``vmap``; the weighted model average
contracts the client axis — GSPMD turns that into the aggregation
all-reduce. Params stay 1d (tensor-parallel over ``model``).

Strategy B — ``sequential`` (cross-silo FL, 100B+ archs): one fully-sharded
(2d: model x data FSDP) parameter set; clients are processed by a
``lax.scan``; each client's K steps use the whole mesh; weighted deltas
accumulate in ``acc_dtype`` (bf16 default: f32 doubles the carry and
XLA:CPU double-buffers scan carries — ablation in EXPERIMENTS §Perf). With
a ``pod`` axis, client groups split across pods (hierarchical FL) and the
final average all-reduces over ``pod``.

Serving: ``serve_step`` = one decoded token against a KV/SSM cache;
``prefill_step`` = full-sequence forward returning last-token logits + the
decode states.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.engine.backends.mesh import MeshBackend
from repro.core.engine.server import get_server_optimizer
from repro.models import registry

PyTree = Any


# ---------------------------------------------------------------------------
# federated train steps
# ---------------------------------------------------------------------------

def make_fed_train_step(cfg: ArchConfig, *, strategy: str = "parallel",
                        remat: bool = True, moe_path: str = "dispatch",
                        use_kernel: bool = False, use_kernel_avg: bool = False,
                        act_spec=None, client_spmd_axes=None,
                        param_specs=None, acc_dtype=jnp.bfloat16,
                        attn_kv_spec=None, moe_shards=1, moe_spmd_axes=None,
                        mesh=None):
    """Returns train_step(params, batches, weights, eta) ->
    (new_params, mean_first_step_loss).

    ``client_spmd_axes``: mesh axes the client vmap dim is sharded over —
    required when ``act_spec`` constrains activations inside the vmap
    (otherwise GSPMD replicates the client dim at the constraint).
    ``mesh``: optional concrete Mesh — with ``use_kernel_avg`` it routes the
    aggregation through the client-sharded Pallas reduction (local
    block-reduce + all-reduce of partials) instead of the plain kernel."""
    if strategy not in ("parallel", "sequential"):
        raise ValueError(f"unknown strategy {strategy!r}")
    loss_fn = registry.loss_fn(cfg, remat=remat, moe_path=moe_path,
                               use_kernel=use_kernel, act_spec=act_spec,
                               attn_kv_spec=attn_kv_spec,
                               moe_shards=moe_shards,
                               moe_spmd_axes=moe_spmd_axes)
    aggregator = "kernel" if use_kernel_avg else "mean"
    server = get_server_optimizer("avg")     # plain FedAvg at server_lr=1

    if strategy == "parallel":
        backend = MeshBackend(mesh, strategy="parallel",
                              client_axes=client_spmd_axes)
        core = backend.make_round_core(loss_fn, aggregator=aggregator,
                                       server=server, server_lr=1.0)

        def train_step(params, batches, weights, eta):
            # batches leaves: (N, K, b, ...); weights: (N,)
            new_params, first_losses, _, _ = core(params, batches, weights,
                                                  eta, ())
            return new_params, jnp.mean(first_losses)

        return train_step

    def train_step(params, batches, weights, eta):
        # batches leaves: (G, Ng, K, b, ...); weights: (G, Ng).  The group
        # count is static at trace time, so the backend core is built here.
        backend = MeshBackend(mesh, strategy="sequential",
                              client_axes=client_spmd_axes,
                              groups=weights.shape[0],
                              param_specs=param_specs, acc_dtype=acc_dtype)
        core = backend.make_round_core(loss_fn, aggregator=aggregator,
                                       server=server, server_lr=1.0)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batches)
        new_params, first_losses, _, _ = core(params, flat,
                                              weights.reshape(-1), eta, ())
        return new_params, jnp.mean(first_losses)

    return train_step


def fed_batch_specs(cfg: ArchConfig, shape: ShapeConfig, *, n_clients: int,
                    k_local: int, groups: Optional[int] = None,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one federated round's batches.

    parallel (groups=None): leaves (N, K, b, ...);
    sequential (groups>=1):  leaves (G, N/G, K, b, ...).
    N * b == shape.global_batch (the assigned input shape is the total
    per-local-step batch across the round's clients).
    """
    assert shape.global_batch % n_clients == 0, (shape, n_clients)
    b = shape.global_batch // n_clients
    S = shape.seq_len
    if groups is not None:
        assert n_clients % groups == 0
        lead: Tuple[int, ...] = (groups, n_clients // groups, k_local, b)
    else:
        lead = (n_clients, k_local, b)
    i32 = jnp.int32
    if cfg.arch_type == "audio":
        return {"tokens": jax.ShapeDtypeStruct(lead + (S,), i32),
                "audio_embeds": jax.ShapeDtypeStruct(
                    lead + (cfg.encoder_seq, cfg.d_model), dtype)}
    specs = {"tokens": jax.ShapeDtypeStruct(
        lead + (S - (cfg.num_patch_tokens if cfg.arch_type == "vlm" else 0),),
        i32)}
    if cfg.arch_type == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_patch_tokens, cfg.d_model), dtype)
    return specs


def fed_weight_specs(n_clients: int,
                     groups: Optional[int] = None) -> jax.ShapeDtypeStruct:
    if groups is not None:
        return jax.ShapeDtypeStruct((groups, n_clients // groups), jnp.float32)
    return jax.ShapeDtypeStruct((n_clients,), jnp.float32)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig, *, long_mode: bool = False,
                    moe_path: str = "dispatch", ring: bool = False):
    decode = registry.decode_fn(cfg, long_mode=long_mode, moe_path=moe_path,
                                ring=ring)

    def serve_step(params, cache, token, pos):
        return decode(params, cache, token, pos)

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, long_mode: bool = False,
                      moe_path: str = "dispatch", use_kernel: bool = False,
                      act_spec=None, attn_kv_spec=None, moe_shards=1,
                      moe_spmd_axes=None):
    """Full-sequence prefill: returns (last-token logits, decode states).

    The readout is applied to the LAST position only — materialising the
    full (B, S, V) logits just to slice one row cost 100+ GB/chip on the
    256k-vocab archs (measured in the first dry-run sweep).
    """
    if registry.is_encdec(cfg):
        def prefill_step(params, batch):
            from repro.models import encdec
            logits, _ = encdec.forward_encdec(params, cfg, batch["tokens"],
                                              batch["audio_embeds"])
            return logits[:, -1]
        return prefill_step

    from repro.models import transformer
    fwd_kw = dict(moe_path=moe_path, use_kernel=use_kernel, act_spec=act_spec,
                  attn_kv_spec=attn_kv_spec, moe_shards=moe_shards,
                  moe_spmd_axes=moe_spmd_axes,
                  global_window=(registry.LONG_GLOBAL_WINDOW if long_mode else None))

    def prefill_step(params, batch):
        feats, aux, states = transformer.forward_lm(
            params, cfg, batch["tokens"], batch.get("patch_embeds"),
            return_states=True, return_features=True, **fwd_kw)
        logits = transformer._readout(params, cfg, feats[:, -1:])
        return logits[:, 0], states

    return prefill_step
