"""Mesh-level FedAvg train steps and serving steps for the assigned archs.

Strategy A — ``parallel`` (cross-device FL): the round's N clients live on
the mesh ``data`` (x ``pod``) axes via ``vmap``; each lane runs K local SGD
steps (``lax.scan``); the weighted model average contracts the client axis —
GSPMD turns that into the aggregation all-reduce. Params stay 1d
(tensor-parallel over ``model``).

Strategy B — ``sequential`` (cross-silo FL, 100B+ archs): one fully-sharded
(2d: model x data FSDP) parameter set; clients are processed by a
``lax.scan``; each client's K steps use the whole mesh; weighted deltas
accumulate in f32. With a ``pod`` axis, client groups split across pods
(hierarchical FL) and the final average all-reduces over ``pod``.

Serving: ``serve_step`` = one decoded token against a KV/SSM cache;
``prefill_step`` = full-sequence forward returning last-token logits + the
decode states.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.engine import aggregators as agg
from repro.core.engine.client import client_update
from repro.models import registry

PyTree = Any


# ---------------------------------------------------------------------------
# federated train steps
# ---------------------------------------------------------------------------

def _local_sgd(loss_fn, params, client_batches, eta):
    """K steps of SGD from the round-start params (the engine's shared
    ClientUpdate — see repro.core.engine.client). Leaves of
    ``client_batches`` have leading K axis."""
    res = client_update(loss_fn, params, client_batches, eta)
    return res.params, res.first_loss


def make_fed_train_step(cfg: ArchConfig, *, strategy: str = "parallel",
                        remat: bool = True, moe_path: str = "dispatch",
                        use_kernel: bool = False, use_kernel_avg: bool = False,
                        act_spec=None, client_spmd_axes=None,
                        param_specs=None, acc_dtype=jnp.bfloat16,
                        attn_kv_spec=None, moe_shards=1, moe_spmd_axes=None):
    """Returns train_step(params, batches, weights, eta) ->
    (new_params, mean_first_step_loss).

    ``client_spmd_axes``: mesh axes the client vmap dim is sharded over —
    required when ``act_spec`` constrains activations inside the vmap
    (otherwise GSPMD replicates the client dim at the constraint)."""
    loss_fn = registry.loss_fn(cfg, remat=remat, moe_path=moe_path,
                               use_kernel=use_kernel, act_spec=act_spec,
                               attn_kv_spec=attn_kv_spec,
                               moe_shards=moe_shards,
                               moe_spmd_axes=moe_spmd_axes)

    if strategy == "parallel":
        def train_step(params, batches, weights, eta):
            # batches leaves: (N, K, b, ...); weights: (N,)
            client_params, first_losses = jax.vmap(
                lambda b: _local_sgd(loss_fn, params, b, eta),
                spmd_axis_name=client_spmd_axes)(batches)
            aggregate = agg.get_aggregator(
                "kernel" if use_kernel_avg else "mean")
            new_params = aggregate(client_params, weights)
            return new_params, jnp.mean(first_losses)

        return train_step

    if strategy == "sequential":
        def constrain(tree):
            # keep the f32 delta accumulator on the params' 2d sharding —
            # without this GSPMD replicates full f32 weights inside the
            # client scan (measured +8 GB/chip on nemotron-340b)
            if param_specs is None:
                return tree
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                tree, param_specs)

        def train_step(params, batches, weights, eta):
            # batches leaves: (G, Ng, K, b, ...); weights: (G, Ng)
            def per_group(group_batches, group_w):
                def client(acc, inp):
                    cb, w = inp
                    cp, first = _local_sgd(loss_fn, params, cb, eta)
                    cp = constrain(cp)
                    # delta accumulation: bf16 by default (f32 doubles the
                    # carry and XLA:CPU double-buffers scan carries; the
                    # f32 ablation is recorded in EXPERIMENTS §Perf)
                    acc = constrain(jax.tree.map(
                        lambda a, c: (a + w.astype(acc_dtype)
                                      * c.astype(acc_dtype)).astype(acc_dtype),
                        acc, cp))
                    return acc, first

                zeros = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params))
                acc, firsts = jax.lax.scan(client, zeros,
                                           (group_batches, group_w))
                return acc, firsts

            accs, firsts = jax.vmap(per_group,
                                    spmd_axis_name=client_spmd_axes)(batches,
                                                                     weights)
            new_params = jax.tree.map(
                lambda p, a: jnp.sum(a, axis=0).astype(p.dtype), params, accs)
            return new_params, jnp.mean(firsts)

        return train_step

    raise ValueError(f"unknown strategy {strategy!r}")


def fed_batch_specs(cfg: ArchConfig, shape: ShapeConfig, *, n_clients: int,
                    k_local: int, groups: Optional[int] = None,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one federated round's batches.

    parallel (groups=None): leaves (N, K, b, ...);
    sequential (groups>=1):  leaves (G, N/G, K, b, ...).
    N * b == shape.global_batch (the assigned input shape is the total
    per-local-step batch across the round's clients).
    """
    assert shape.global_batch % n_clients == 0, (shape, n_clients)
    b = shape.global_batch // n_clients
    S = shape.seq_len
    if groups is not None:
        assert n_clients % groups == 0
        lead: Tuple[int, ...] = (groups, n_clients // groups, k_local, b)
    else:
        lead = (n_clients, k_local, b)
    i32 = jnp.int32
    if cfg.arch_type == "audio":
        return {"tokens": jax.ShapeDtypeStruct(lead + (S,), i32),
                "audio_embeds": jax.ShapeDtypeStruct(
                    lead + (cfg.encoder_seq, cfg.d_model), dtype)}
    specs = {"tokens": jax.ShapeDtypeStruct(
        lead + (S - (cfg.num_patch_tokens if cfg.arch_type == "vlm" else 0),),
        i32)}
    if cfg.arch_type == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_patch_tokens, cfg.d_model), dtype)
    return specs


def fed_weight_specs(n_clients: int,
                     groups: Optional[int] = None) -> jax.ShapeDtypeStruct:
    if groups is not None:
        return jax.ShapeDtypeStruct((groups, n_clients // groups), jnp.float32)
    return jax.ShapeDtypeStruct((n_clients,), jnp.float32)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig, *, long_mode: bool = False,
                    moe_path: str = "dispatch", ring: bool = False):
    decode = registry.decode_fn(cfg, long_mode=long_mode, moe_path=moe_path,
                                ring=ring)

    def serve_step(params, cache, token, pos):
        return decode(params, cache, token, pos)

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, long_mode: bool = False,
                      moe_path: str = "dispatch", use_kernel: bool = False,
                      act_spec=None, attn_kv_spec=None, moe_shards=1,
                      moe_spmd_axes=None):
    """Full-sequence prefill: returns (last-token logits, decode states).

    The readout is applied to the LAST position only — materialising the
    full (B, S, V) logits just to slice one row cost 100+ GB/chip on the
    256k-vocab archs (measured in the first dry-run sweep).
    """
    if registry.is_encdec(cfg):
        def prefill_step(params, batch):
            from repro.models import encdec
            logits, _ = encdec.forward_encdec(params, cfg, batch["tokens"],
                                              batch["audio_embeds"])
            return logits[:, -1]
        return prefill_step

    from repro.models import transformer
    fwd_kw = dict(moe_path=moe_path, use_kernel=use_kernel, act_spec=act_spec,
                  attn_kv_spec=attn_kv_spec, moe_shards=moe_shards,
                  moe_spmd_axes=moe_spmd_axes,
                  global_window=(registry.LONG_GLOBAL_WINDOW if long_mode else None))

    def prefill_step(params, batch):
        feats, aux, states = transformer.forward_lm(
            params, cfg, batch["tokens"], batch.get("patch_embeds"),
            return_states=True, return_features=True, **fwd_kw)
        logits = transformer._readout(params, cfg, feats[:, -1:])
        return logits[:, 0], states

    return prefill_step
