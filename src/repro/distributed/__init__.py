from repro.distributed import sharding, strategies

__all__ = ["sharding", "strategies"]
