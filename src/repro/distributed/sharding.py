"""Sharding rules: params / batches / decode caches -> PartitionSpec trees.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod (repro.launch.mesh). Rules are path-based over the param pytrees
produced by ``repro.models`` and divisibility-aware: a dim is only sharded if
the mesh axis divides it evenly, so every assigned architecture lowers on the
fixed 16x16 mesh even when (e.g.) num_heads=28 or kv=8 don't divide 16 —
GSPMD then picks the collectives, which the roofline analysis reads back.

Two parameter layouts:
* ``1d`` (tensor-parallel): matmul weights sharded over ``model`` only —
  column-parallel for up-projections (wq/wk/wv/gate/up/lm_head/in_proj),
  row-parallel for down-projections (wo/down/out_proj). Params fit per-chip
  for archs up to ~40B at bf16 on a 256-chip pod.
* ``2d`` (tensor-parallel + FSDP): additionally shard the other matmul dim
  over ``data`` (ZeRO-3-style all-gather at use). Used for mixtral-8x22b and
  nemotron-4-340b.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any

# parent names whose kernels are column-parallel (shard output dim) vs
# row-parallel (shard input/contracting dim)
COL_PARALLEL = {"wq", "wk", "wv", "gate", "up", "lm_head", "in_proj",
                "fc", "fc1", "fc2", "out"}
ROW_PARALLEL = {"wo", "down", "out_proj"}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= _axis_size(mesh, a)
    return size > 1 and dim % size == 0


def use_2d_params(cfg: ArchConfig, mesh: Mesh,
                  bytes_per_param: int = 2,
                  per_chip_budget_gb: float = 6.0) -> bool:
    """2d layout when 1d model-axis sharding would blow the per-chip budget."""
    from repro.models import registry
    model = _axis_size(mesh, "model")
    gb = registry.param_count(cfg) * bytes_per_param / model / 1e9
    return gb > per_chip_budget_gb


def _param_rule(path_keys: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: ArchConfig, mesh: Mesh, two_d: bool,
                fsdp_axes: Tuple[str, ...] = ("data",)) -> P:
    """PartitionSpec for one param leaf; leading stack dims get None."""
    keys = [str(k) for k in path_keys]
    last = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    n_lead = len(shape) - _rule_ndim(last, parent, shape)
    lead = (None,) * max(n_lead, 0)

    def spec(*tail):
        return P(*(lead + tail))

    data_ax = (fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]) \
        if two_d else None

    if last == "embedding":                      # (V, d)
        v_ax = "model" if _div(shape[-2], mesh, "model") else None
        d_ax = data_ax if (two_d and _div(shape[-1], mesh, data_ax)) else None
        return spec(v_ax, d_ax)
    if last == "kernel":
        if parent in COL_PARALLEL:               # (in, out): col-parallel
            out_ax = "model" if _div(shape[-1], mesh, "model") else None
            in_ax = data_ax if (two_d and _div(shape[-2], mesh, data_ax)) else None
            return spec(in_ax, out_ax)
        if parent in ROW_PARALLEL:               # (in, out): row-parallel
            in_ax = "model" if _div(shape[-2], mesh, "model") else None
            out_ax = data_ax if (two_d and _div(shape[-1], mesh, data_ax)) else None
            return spec(in_ax, out_ax)
        if parent == "router":                   # small: replicated
            return spec(*(None,) * 2)
        if len(shape) >= 4:                      # conv kernels (cnn): replicate
            return spec(*(None,) * 4)
        return spec(*(None,) * min(len(shape), 2))
    if last == "bias":
        if parent in COL_PARALLEL and _div(shape[-1], mesh, "model"):
            return spec("model")
        return spec(None)
    if last in ("gate", "up", "down") and len(shape) >= 3:
        # MoE expert banks: (E, d, f) / (E, f, d). Expert-parallel over
        # 'model' when E divides it; otherwise shard the wide FFN dim.
        E = shape[-3]
        if _div(E, mesh, "model"):
            d_ax = data_ax if (two_d and _div(shape[-2], mesh, data_ax)) else None
            return spec("model", d_ax, None)
        wide = -1 if last in ("gate", "up") else -2
        axes = [None, None, None]
        if _div(shape[wide], mesh, "model"):
            axes[wide] = "model"
        other = -2 if wide == -1 else -1
        if two_d and _div(shape[other], mesh, data_ax):
            axes[other] = data_ax
        return spec(*axes)
    if last in ("conv_w", "conv_b", "A_log", "D", "dt_bias", "scale"):
        return spec(*(None,) * _rule_ndim(last, parent, shape))
    # default: replicate
    return P(*(None,) * len(shape))


def _rule_ndim(last: str, parent: str, shape) -> int:
    """Trailing dims the rule applies to (rest are stacked leading dims)."""
    if last == "embedding" or last == "kernel":
        if len(shape) >= 4 and last == "kernel" and parent not in COL_PARALLEL \
                and parent not in ROW_PARALLEL and parent != "router":
            return 4                              # cnn conv kernel
        return 2
    if last in ("gate", "up", "down") and len(shape) >= 3:
        return 3
    if last in ("bias", "conv_b", "A_log", "D", "dt_bias", "scale"):
        return 1
    if last == "conv_w":
        return 2
    return len(shape)


def param_pspecs(cfg: ArchConfig, shapes: PyTree, mesh: Mesh,
                 two_d: bool = False,
                 fsdp_axes: Tuple[str, ...] = ("data",)) -> PyTree:
    """PartitionSpec tree matching a params (shape) pytree.

    ``fsdp_axes``: mesh axes the FSDP (2d) dim shards over — ("data",) on a
    single pod; ("data", "pod") to additionally shard params across pods
    (needed for nemotron-4-340b, whose f32 round state exceeds one pod's
    HBM)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = []
    for path, leaf in flat:
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(_param_rule(keys, tuple(leaf.shape), cfg, mesh, two_d,
                                 fsdp_axes))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the FL client dimension shards over (strategy A)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fed_batch_pspecs(batch_shapes: PyTree, mesh: Mesh,
                     strategy: str) -> PyTree:
    """Round batches (N, K, b, ...).

    Strategy A (parallel): N sharded over pod+data.
    Strategy B (sequential): N is a scan axis; batch dim b shards over data,
    and the client axis shards over 'pod' when present (hierarchical FL).
    """
    ca = client_axes(mesh)

    def rule(leaf):
        nd = len(leaf.shape)
        if strategy == "parallel":
            return P(ca, *(None,) * (nd - 1))
        # sequential: (N, K, b, ...): b over data if divisible
        axes = [None] * nd
        if "pod" in mesh.axis_names and leaf.shape[0] % _axis_size(mesh, "pod") == 0:
            axes[0] = "pod"
        if nd >= 3 and leaf.shape[2] % _axis_size(mesh, "data") == 0:
            axes[2] = "data"
        return P(*axes)

    return jax.tree.map(rule, batch_shapes)


def serve_batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def serve_input_pspecs(batch: int, mesh: Mesh) -> P:
    """Token batch (B,) for decode; (B, S) for prefill handled by caller."""
    ba = serve_batch_axes(mesh)
    size = 1
    for a in ba:
        size *= _axis_size(mesh, a)
    return P(ba) if batch % size == 0 else P(None)


def cache_pspecs(cfg: ArchConfig, cache_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Decode-cache PartitionSpecs.

    KV caches (..., B, S, KV, hd): prefer batch over data; heads over model
    when divisible, else shard S over model (and over data too when B=1,
    e.g. long_500k single-stream decode).
    SSM states (..., B, H, N, P): batch over data, heads over model.
    """
    ba = serve_batch_axes(mesh)
    dsize = 1
    for a in ba:
        dsize *= _axis_size(mesh, a)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        last = keys[-1]
        shape = leaf.shape
        if last in ("ks", "vs", "krs", "vrs"):
            # int8-cache scales (..., B, L, KV, 1): batch over data only
            lead = (None,) * (len(shape) - 4)
            b_ax2: Any = ba if shape[-4] % dsize == 0 else None
            specs.append(P(*lead, b_ax2, None, None, None))
        elif last in ("k", "v", "kr", "vr", "xk", "xv"):
            B, S, KV, hd = shape[-4:]
            lead = (None,) * (len(shape) - 4)
            b_ax: Any = ba if B % dsize == 0 else None
            msize = _axis_size(mesh, "model")
            if KV % msize == 0:
                specs.append(P(*lead, b_ax, None, "model", None))
            elif hd % msize == 0:
                # kv heads don't divide the model axis: shard head_dim —
                # unlike seq-sharding this keeps the decode cache update
                # (dynamic slice at a traced position) gather-free
                specs.append(P(*lead, b_ax, None, None, "model"))
            elif b_ax is None and S % (dsize * msize) == 0:
                specs.append(P(*lead, None, ba + ("model",), None, None))
            elif S % msize == 0:
                specs.append(P(*lead, b_ax, "model", None, None))
            else:
                specs.append(P(*lead, b_ax, None, None, None))
        elif last == "ssm":
            B, H, N, Pd = shape[-4:]
            lead = (None,) * (len(shape) - 4)
            b_ax = ba if B % dsize == 0 else None
            h_ax = "model" if H % _axis_size(mesh, "model") == 0 else None
            specs.append(P(*lead, b_ax, h_ax, None, None))
        elif last == "conv":
            B, t, C = shape[-3:]
            lead = (None,) * (len(shape) - 3)
            b_ax = ba if B % dsize == 0 else None
            c_ax = "model" if C % _axis_size(mesh, "model") == 0 else None
            specs.append(P(*lead, b_ax, None, c_ax))
        else:
            specs.append(P(*(None,) * len(shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
