"""Pytree checkpointing with numpy + json (no orbax offline).

A checkpoint is a directory: ``arrays.npz`` (flattened leaves keyed by path)
plus ``meta.json`` (server round state: round index, K_r, eta_r, loss-tracker
window, rng seed...). Atomic via write-to-tmp + rename.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params: PyTree,
                    meta: Optional[Dict] = None) -> None:
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(path)) or ".")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(params))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta or {}, f, indent=2, default=str)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_checkpoint(path: str, like: PyTree) -> Tuple[PyTree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), meta
