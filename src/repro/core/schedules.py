"""K_r and eta_r decay schedules — the paper's contribution (Table 3).

| schedule     | K_r                          | eta_r                  |
|--------------|------------------------------|------------------------|
| dsgd         | 1                            | eta0                   |
| fixed        | K0                           | eta0                   |
| K_r-rounds   | ceil(K0 / r^(1/3))   (Eq.10) | eta0                   |
| K_r-error    | ceil(K0 * (F_r/F0)^(1/3)) (13)| eta0                  |
| K_r-step     | K0/10 once val plateaus      | eta0                   |
| eta_r-rounds | K0                           | eta0 / sqrt(r) (Eq.12) |
| eta_r-error  | K0                           | eta0*sqrt(F_r/F0) (14) |
| eta_r-step   | K0                           | eta0/10 once plateaued |

Beyond-paper: ``cosine`` K decay and ``quantize_k`` (snap K_r to a geometric
grid to bound the number of distinct compiled round functions).
"""
from __future__ import annotations

import math
from typing import Optional

from repro.configs.base import FedConfig
from repro.core.loss_tracker import LossTracker, PlateauDetector

K_SCHEDULES = ("fixed", "dsgd", "rounds", "error", "step", "cosine")
ETA_SCHEDULES = ("fixed", "rounds", "error", "step")


def quantize_k(k: int, k0: int, ratio: float = 1.35) -> int:
    """Snap k to the geometric grid {k0, k0/ratio, k0/ratio^2, ...}."""
    if k >= k0:
        return k0
    if k <= 1:
        return 1
    # grid level closest from below
    level = math.floor(math.log(k0 / k) / math.log(ratio) + 1e-9)
    return max(1, int(round(k0 / ratio ** level)))


class DecayController:
    """Produces (K_r, eta_r) per round and ingests the feedback signals the
    schedules need: first-step client losses (Eq. 15 rolling estimate) and
    validation metrics (plateau detection for the -step heuristic)."""

    def __init__(self, fed: FedConfig):
        if fed.k_schedule not in K_SCHEDULES:
            raise ValueError(f"k_schedule {fed.k_schedule!r} not in {K_SCHEDULES}")
        if fed.eta_schedule not in ETA_SCHEDULES:
            raise ValueError(f"eta_schedule {fed.eta_schedule!r} not in {ETA_SCHEDULES}")
        self.fed = fed
        self.tracker = LossTracker(window=fed.loss_window)
        self.plateau = PlateauDetector(patience=fed.plateau_patience)
        self._f0: Optional[float] = None

    # ---------------- feedback ----------------
    def observe_round_losses(self, mean_first_step_loss: float) -> None:
        """Feed (1/N) sum_c f_c(x_r, xi_c0) for the just-finished round."""
        self.tracker.push(mean_first_step_loss)
        if self._f0 is None:
            self._f0 = float(mean_first_step_loss)

    def observe_validation(self, val_error: float) -> None:
        self.plateau.push(val_error)

    # ---------------- checkpointing ----------------
    def state_dict(self) -> dict:
        """Feedback state under the legacy checkpoint keys (both engines'
        ``meta["ctrl"]`` payloads delegate here, DESIGN.md §14)."""
        return {"f0": self._f0, "window": list(self.tracker._buf),
                "plateau": [self.plateau.best, self.plateau.stale,
                            self.plateau.plateaued]}

    def load_state_dict(self, c: dict) -> None:
        self.tracker._buf.clear()
        for v in c["window"]:
            self.tracker.push(v)
        self._f0 = c["f0"]
        best, stale, plateaued = c["plateau"]
        self.plateau.best = best
        self.plateau.stale = int(stale)
        self.plateau.plateaued = bool(plateaued)

    # ---------------- queries ----------------
    def _error_ratio(self) -> float:
        """F_r / F_0 with the Eq. 15 rolling window; 1.0 until warm."""
        if self._f0 is None or not self.tracker.full:
            return 1.0
        f_r = self.tracker.value()
        return max(min(f_r / max(self._f0, 1e-12), 1.0), 0.0)

    def k_for_round(self, r: int) -> int:
        fed = self.fed
        s = fed.k_schedule
        if s == "dsgd":
            return 1
        if s == "fixed":
            k = fed.k0
        elif s == "rounds":
            k = math.ceil(fed.k0 / r ** (1.0 / 3.0))
        elif s == "error":
            k = math.ceil(fed.k0 * self._error_ratio() ** (1.0 / 3.0))
        elif s == "step":
            k = max(int(fed.k0 / fed.step_decay_factor), 1) \
                if self.plateau.plateaued else fed.k0
        elif s == "cosine":
            t = min(r / max(fed.rounds, 1), 1.0)
            k = math.ceil(fed.k_min + 0.5 * (fed.k0 - fed.k_min)
                          * (1 + math.cos(math.pi * t)))
        else:
            raise AssertionError(s)
        k = max(min(k, fed.k0), fed.k_min)
        if fed.k_quantize:
            # the grid anchor is fed.k0 unless a sweep pins an explicit
            # k_grid0: fleet points with different k0 but one shared anchor
            # snap to IDENTICAL grid values, so their bucket shapes — and
            # hence their AOT executables — coincide (DESIGN.md §12)
            k = quantize_k(k, getattr(fed, "k_grid0", None) or fed.k0)
        return k

    def eta_for_round(self, r: int) -> float:
        fed = self.fed
        s = fed.eta_schedule
        if s == "fixed":
            return fed.eta0
        if s == "rounds":
            return fed.eta0 / math.sqrt(r)
        if s == "error":
            return fed.eta0 * math.sqrt(self._error_ratio())
        if s == "step":
            return fed.eta0 / fed.step_decay_factor if self.plateau.plateaued \
                else fed.eta0
        raise AssertionError(s)


def schedule_preview(fed: FedConfig, rounds: int):
    """K_r trajectory for loss-free schedules (rounds/cosine/fixed/dsgd)."""
    ctrl = DecayController(fed)
    return [ctrl.k_for_round(r) for r in range(1, rounds + 1)]
