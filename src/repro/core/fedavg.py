"""FedAvg with decaying local steps — the training engine (Algorithm 1).

One communication round, jitted end-to-end:

    client_params, first_losses = vmap_c [ K-step local SGD from x_r ]
    x_{r+1} = server_update( sum_c p_c * client_params )

``K`` is the leading axis of the round's batch tensors, so a K-decay schedule
changes the compiled shape; XLA caches one executable per distinct K (the
``k_quantize`` option bounds that set — see DESIGN.md §5).

The engine is model-agnostic: it takes ``loss_fn(params, batch) ->
(loss, metrics)`` and initial params, so the same engine trains the paper's
convex/DNN/CNN/GRU tasks and the assigned transformer architectures.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import FedConfig
from repro.core.runtime_model import RuntimeModel
from repro.core.schedules import DecayController
from repro.data import pipeline
from repro.data.synthetic import FederatedData

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]


# ---------------------------------------------------------------------------
# round function
# ---------------------------------------------------------------------------

def make_round_fn(loss_fn: LossFn, *, server: str = "avg",
                  server_lr: float = 1.0, use_kernel_avg: bool = False):
    """Build the jitted FedAvg round.

    round_fn(params, batches{(N,K,b,...)}, weights (N,), eta, server_state)
        -> (new_params, first_losses (N,), mean_last_loss, server_state)
    """
    if server == "fedadam":
        srv_init, srv_update = optim.fedadam_server()
    else:
        srv_init, srv_update = None, None

    def local_sgd(params, client_batches, eta):
        def step(p, batch):
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            p = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype), p, grads)
            return p, loss

        final, losses = jax.lax.scan(step, params, client_batches)
        return final, losses[0], losses[-1]

    def round_fn(params, batches, weights, eta, server_state):
        client_params, first_losses, last_losses = jax.vmap(
            local_sgd, in_axes=(None, 0, None))(params, batches, eta)
        if use_kernel_avg:
            from repro.kernels import ops as kops
            avg = kops.fedavg_reduce_tree(client_params, weights)
        else:
            avg = jax.tree.map(
                lambda cp: jnp.einsum("c,c...->...", weights.astype(jnp.float32),
                                      cp.astype(jnp.float32)).astype(cp.dtype),
                client_params)
        if server == "fedadam":
            # pseudo-gradient = -(avg - params); Adam server step (Reddi'21)
            delta = optim.tree_sub(params, avg)
            updates, server_state = srv_update(delta, server_state, params,
                                               server_lr)
            new_params = optim.apply_updates(params, updates)
        else:
            # plain FedAvg (server_lr=1 recovers Algorithm 1 line 11 exactly)
            new_params = jax.tree.map(
                lambda p, a: (p + server_lr * (a - p)).astype(p.dtype),
                params, avg)
        return new_params, first_losses, jnp.mean(last_losses), server_state

    return jax.jit(round_fn), srv_init


# ---------------------------------------------------------------------------
# history
# ---------------------------------------------------------------------------

@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    k: List[int] = field(default_factory=list)
    eta: List[float] = field(default_factory=list)
    wall_clock_s: List[float] = field(default_factory=list)   # cumulative, Eq. 5
    sgd_steps: List[int] = field(default_factory=list)        # cumulative
    train_loss: List[float] = field(default_factory=list)     # Eq. 15 round mean
    min_train_loss: List[float] = field(default_factory=list) # Fig. 1 metric
    val_rounds: List[int] = field(default_factory=list)
    val_error: List[float] = field(default_factory=list)
    max_val_acc: List[float] = field(default_factory=list)    # Fig. 2 metric

    def as_dict(self) -> Dict[str, list]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class FedAvgTrainer:
    def __init__(self, loss_fn: LossFn, init_params: PyTree,
                 data: FederatedData, fed: FedConfig,
                 runtime: RuntimeModel,
                 eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
                 use_kernel_avg: bool = False):
        self.loss_fn = loss_fn
        self.params = init_params
        self.data = data
        self.fed = fed
        self.runtime = runtime
        self.eval_fn = eval_fn
        self.ctrl = DecayController(fed)
        self.round_fn, srv_init = make_round_fn(
            loss_fn, server=fed.server_optimizer, server_lr=fed.server_lr,
            use_kernel_avg=use_kernel_avg)
        self.server_state = srv_init(init_params) if srv_init else ()
        self.history = History()
        self._np_rng = np.random.default_rng(fed.seed)
        self._wall = 0.0
        self._steps = 0
        self._min_loss = float("inf")
        self._max_acc = 0.0

    def run(self, rounds: Optional[int] = None, eval_every: int = 10,
            verbose: bool = False) -> History:
        rounds = rounds if rounds is not None else self.fed.rounds
        fed, data = self.fed, self.data
        for r in range(1, rounds + 1):
            k_r = self.ctrl.k_for_round(r)
            eta_r = self.ctrl.eta_for_round(r)

            ids = pipeline.sample_clients(self._np_rng, data,
                                          fed.clients_per_round)
            batches = pipeline.round_batches(self._np_rng, data, ids, k_r,
                                             fed.batch_size)
            weights = pipeline.client_weights(data, ids)
            self.params, first_losses, last_loss, self.server_state = \
                self.round_fn(self.params,
                              {k: jnp.asarray(v) for k, v in batches.items()},
                              jnp.asarray(weights), jnp.float32(eta_r),
                              self.server_state)

            round_loss = float(jnp.mean(first_losses))
            self.ctrl.observe_round_losses(round_loss)
            cost = self.runtime.round_cost(k_r)
            self._wall += cost.wall_clock_s
            self._steps += cost.sgd_steps
            self._min_loss = min(self._min_loss, round_loss)

            h = self.history
            h.rounds.append(r)
            h.k.append(k_r)
            h.eta.append(eta_r)
            h.wall_clock_s.append(self._wall)
            h.sgd_steps.append(self._steps)
            h.train_loss.append(round_loss)
            h.min_train_loss.append(self._min_loss)

            if self.eval_fn is not None and (r % eval_every == 0 or r == rounds):
                metrics = self.eval_fn(self.params)
                err = metrics.get("error", 1.0 - metrics.get("acc", 0.0))
                self.ctrl.observe_validation(err)
                self._max_acc = max(self._max_acc, metrics.get("acc", 0.0))
                h.val_rounds.append(r)
                h.val_error.append(err)
                h.max_val_acc.append(self._max_acc)
                if verbose:
                    print(f"round {r:5d} K={k_r:3d} eta={eta_r:.4f} "
                          f"loss={round_loss:.4f} val_err={err:.4f} "
                          f"W={self._wall:.1f}s steps={self._steps}")
        return self.history


def make_eval_fn(loss_fn: LossFn, data: FederatedData, batch_size: int = 128):
    """Validation accuracy/error over the global validation split."""
    batches = pipeline.val_batches(data, batch_size)

    @jax.jit
    def eval_batch(params, batch):
        loss, metrics = loss_fn(params, batch)
        return loss, metrics.get("acc", jnp.zeros(()))

    def eval_fn(params) -> Dict[str, float]:
        losses, accs = [], []
        for b in batches:
            l, a = eval_batch(params, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(l))
            accs.append(float(a))
        acc = float(np.mean(accs))
        return {"loss": float(np.mean(losses)), "acc": acc, "error": 1.0 - acc}

    return eval_fn
