"""FedAvg with decaying local steps — compatibility facade + reference loop.

The training engine now lives in ``repro.core.engine`` (see DESIGN.md §6):
ClientUpdate / Aggregator / ServerOptimizer compose into a round, a
RoundScheduler groups rounds into K-buckets executed as single jitted
multi-round scans, and a BatchPrefetcher overlaps host batch construction
with device compute. This module re-exports the public names that
historically lived here (``FedAvgTrainer``, ``History``, ``make_round_fn``,
``make_eval_fn``) and keeps the *seed per-round loop* as
``run_reference_rounds`` — the bitwise oracle the engine's bucketed
execution is verified against (tests/test_engine.py) and the baseline for
the dispatch-amortisation benchmark.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.engine.round import make_round_fn
from repro.core.engine.server import get_server_optimizer
from repro.core.engine.trainer import FedAvgTrainer, History, make_eval_fn
from repro.core.schedules import DecayController
from repro.data import pipeline
from repro.data.synthetic import FederatedData

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]

__all__ = ["FedAvgTrainer", "History", "make_eval_fn", "make_round_fn",
           "ReferenceRun", "run_reference_rounds"]


class ReferenceRun(NamedTuple):
    params: PyTree
    losses: np.ndarray       # per-round mean first-step losses
    ks: List[int]            # per-round K_r actually executed
    round_fn: Any            # pass back in to reuse warm executables


def run_reference_rounds(loss_fn: LossFn, params: PyTree,
                         data: FederatedData, fed: FedConfig,
                         rounds: int, *, round_fn=None) -> ReferenceRun:
    """The seed trainer's inner loop, verbatim: one jitted round per
    dispatch, one blocking ``float(jnp.mean(...))`` sync per round, one XLA
    compile per distinct K_r. Follows the configured K/eta schedules via a
    fresh ``DecayController`` (loss feedback observed per round, exactly as
    the seed trainer did).

    The bitwise-parity oracle for the bucketed engine (tests/test_engine.py)
    and the baseline for the dispatch-amortisation benchmark
    (benchmarks/schedules_bench.py) — pass ``round_fn`` from a previous run
    to time a warm pass.
    """
    ctrl = DecayController(fed)
    if round_fn is None:
        round_fn, _ = make_round_fn(loss_fn, server=fed.server_optimizer,
                                    server_lr=fed.server_lr)
    server_state = (() if fed.server_optimizer == "avg"
                    else get_server_optimizer(fed.server_optimizer).init(params))
    rng = np.random.default_rng(fed.seed)
    losses, ks = [], []
    for r in range(1, rounds + 1):
        k_r = ctrl.k_for_round(r)
        eta_r = ctrl.eta_for_round(r)
        ids = pipeline.sample_clients(rng, data, fed.clients_per_round)
        batches = pipeline.round_batches(rng, data, ids, k_r, fed.batch_size)
        weights = pipeline.client_weights(data, ids)
        params, first_losses, _, server_state = round_fn(
            params, {key: jnp.asarray(v) for key, v in batches.items()},
            jnp.asarray(weights), jnp.float32(eta_r), server_state)
        loss = float(jnp.mean(first_losses))           # the per-round sync
        ctrl.observe_round_losses(loss)
        losses.append(loss)
        ks.append(k_r)
    return ReferenceRun(params, np.asarray(losses), ks, round_fn)
