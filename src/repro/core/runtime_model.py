"""The paper's FedAvg runtime model (§3.2, Eq. 3-5) and compute accounting.

Nominal per-round wall-clock for client c:
    W_r^c = |x|/D^c + K_r * beta^c + |x|/U^c          (Eq. 3)
The server waits for the straggler:
    W_r = max_c W_r^c                                  (Eq. 4)
Homogeneous-client total over R rounds:
    W = R(|x|/D + |x|/U) + beta * sum_r K_r            (Eq. 5)

We implement both the homogeneous model the paper evaluates with and an
optional heterogeneous straggler model (lognormal client speed spread) for
sensitivity studies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import RuntimeModelConfig


@dataclass
class RoundCost:
    wall_clock_s: float
    sgd_steps: int
    uplink_mbit: float
    downlink_mbit: float
    # decode queries the server answered during this round's wall time
    # (mixed train+serve cost model, DESIGN.md §14); 0.0 without serving
    serve_queries: float = 0.0


class RuntimeModel:
    def __init__(self, model_size_mbit: float, cfg: RuntimeModelConfig,
                 clients_per_round: int = 1, heterogeneity: float = 0.0,
                 seed: int = 0, uplink_compression: float = 1.0,
                 downlink_compression: float = 1.0,
                 serve_qps: float = 0.0, serve_query_s: float = 0.0):
        """heterogeneity: sigma of lognormal speed multipliers per sampled
        client, applied to the client's WHOLE round time (compute beta and
        both wire legs — a slow client is slow end to end); 0 reproduces
        the paper's homogeneous Eq. 5.

        ``uplink_compression`` / ``downlink_compression``: ratios by which
        the transport codecs shrink the client's uploaded delta and the
        server's broadcast delta (DESIGN.md §8/§8.6); 1.0 is the paper's
        uncompressed |x| on that leg. ``FedAvgTrainer`` sets both from the
        configured codecs, so modelled wall-clock and bytes-on-wire charge
        each wire leg what its codec actually ships."""
        self.size = model_size_mbit
        self.cfg = cfg
        self.n = clients_per_round
        self.het = heterogeneity
        self.uplink_compression = float(uplink_compression)
        self.downlink_compression = float(downlink_compression)
        #: optional {level: ratio} map for the adaptive downlink codec
        #: (DESIGN.md §10.4): set by the trainer, consulted by
        #: ``round_cost(..., downlink_level=...)``. None -> the fixed
        #: ``downlink_compression`` ratio charges every round.
        self.downlink_level_ratios = None
        # mixed train+serve cost (DESIGN.md §14): the server spends
        # rho = qps * query_s of every wall second answering decode
        # queries, so round coordination runs on the remaining 1 - rho —
        # the M/G/1-style utilisation stretch 1/(1-rho) on the round clock.
        self.serve_qps = float(serve_qps)
        self.serve_query_s = float(serve_query_s)
        rho = self.serve_qps * self.serve_query_s
        if rho >= 1.0:
            raise ValueError(
                f"serve utilisation rho = serve_qps * serve_query_s = "
                f"{rho:.3f} >= 1: the server spends every second answering "
                f"queries and training never progresses — lower serve.qps "
                f"or serve.query_ms")
        self._serve_stretch = 1.0 / (1.0 - rho) if rho > 0 else 1.0
        self._seed = int(seed)
        self._rng = np.random.default_rng(seed)

    @property
    def uplink_mbit_per_client(self) -> float:
        """Encoded uplink size (Eq. 3's |x|/U numerator under compression)."""
        return self.size / self.uplink_compression

    @property
    def downlink_mbit_per_client(self) -> float:
        """Encoded broadcast size (Eq. 3's |x|/D numerator; DESIGN.md
        §8.6). The reference-delta payload is one encoding of |x|, shipped
        to every client."""
        return self.size / self.downlink_compression

    def comm_time(self) -> float:
        """Per-round communication term, HET-FREE: the homogeneous-client
        (Eq. 5) mean a lognormal(0, sigma) multiplier would scale. Use
        ``round_cost`` for straggler-aware per-round draws — mixing the two
        under heterogeneity > 0 under-reports stragglers (they are
        reconciled by construction only at heterogeneity == 0, where
        ``total_time(ks) == sum(round_cost(k).wall_clock_s)``)."""
        return (self.downlink_mbit_per_client / self.cfg.download_mbps
                + self.uplink_mbit_per_client / self.cfg.upload_mbps)

    def _leg_mbit(self, downlink_level: Optional[int] = None
                  ) -> Tuple[float, float]:
        """(uplink, downlink) encoded mbit per client for one round, with
        the adaptive downlink's per-level charge applied (DESIGN.md §10.4)."""
        up = self.uplink_mbit_per_client
        down = self.downlink_mbit_per_client
        if self.downlink_level_ratios is not None and \
                downlink_level is not None and downlink_level >= 0:
            if downlink_level == 0:
                down = 0.0
            else:
                ratio = self.downlink_level_ratios.get(
                    downlink_level, self.downlink_compression)
                down = self.size / float(ratio)
        return up, down

    def _base_seconds(self, k: int,
                      downlink_level: Optional[int] = None) -> float:
        """Eq. 3 for the nominal (speed-multiplier 1.0) client."""
        up, down = self._leg_mbit(downlink_level)
        return (down / self.cfg.download_mbps
                + k * self.cfg.beta_seconds
                + up / self.cfg.upload_mbps)

    def draw_client_times(self, round_idx: Optional[int],
                          client_ids: Sequence[int], k: int, *,
                          downlink_level: Optional[int] = None) -> np.ndarray:
        """Seeded per-client round durations (Eq. 3 x lognormal multiplier).

        This is the one source of the heterogeneity draw: ``round_cost``
        consumes it (stream mode) so the straggler max and any per-client
        consumer (the async event clock) see the SAME speed model — they
        reconcile with the het-free ``comm_time`` mean exactly at
        ``heterogeneity == 0``, where every entry is ``_base_seconds``.

        Two reproducible modes:

          * ``round_idx=None`` — stream mode: multipliers come off the
            model's own ``self._rng`` stream (checkpointed as
            ``runtime_rng``), one draw per entry of ``client_ids``. This is
            the historical ``round_cost`` draw bit-for-bit.
          * ``round_idx`` given — counter mode: each client's multiplier is
            drawn from ``default_rng([seed, round_idx, client_id])``, so a
            duration is a pure function of (seed, dispatch index, client) —
            order-independent, replayable without any saved rng state. The
            async engine's event clock is built on this mode.
        """
        ids = np.asarray(client_ids, dtype=np.int64)
        base = self._base_seconds(k, downlink_level)
        if self.het <= 0:
            return np.full(ids.shape[0], base, dtype=np.float64)
        if round_idx is None:
            mult = self._rng.lognormal(0.0, self.het, size=ids.shape[0])
        else:
            mult = np.array([
                np.random.default_rng(
                    [self._seed, int(round_idx), int(c)]
                ).lognormal(0.0, self.het) for c in ids])
        return base * mult

    def round_cost(self, k: int, downlink_level: Optional[int] = None
                   ) -> RoundCost:
        """Eq. 3/4: straggler max over the round's client draws.

        ``downlink_level``: the adaptive codec's per-round level
        (DESIGN.md §10.4) — consulted only when ``downlink_level_ratios``
        is set. Level 0 ships no broadcast (zero downlink mbit/time);
        levels in the map charge that level's ratio; -1/None (fixed-rate
        codec or padding round) charges the configured ratio."""
        up, down = self._leg_mbit(downlink_level)
        if self.het > 0:
            # one speed multiplier per client, on compute AND both wire
            # legs — keeps round_cost consistent with the documented
            # beta/U/D spread (comm_time stays the het-free mean). Stream
            # mode keeps the historical self._rng draw bit-for-bit: the
            # scalar base distributes over the elementwise product, so
            # max(base * mult) == base * max(mult) exactly.
            times = self.draw_client_times(None, np.arange(self.n), k,
                                           downlink_level=downlink_level)
            wall = float(np.max(times))
        else:
            wall = self._base_seconds(k, downlink_level)
        wall *= self._serve_stretch
        return RoundCost(wall_clock_s=wall,
                         sgd_steps=k * self.n,
                         uplink_mbit=up * self.n,
                         downlink_mbit=down * self.n,
                         serve_queries=self.serve_qps * wall)

    def total_time(self, ks: Sequence[int]) -> float:
        """Eq. 5 (homogeneous)."""
        r = len(ks)
        return r * self.comm_time() + self.cfg.beta_seconds * float(np.sum(ks))

    def total_sgd_steps(self, ks: Sequence[int]) -> int:
        return int(np.sum(ks)) * self.n

    def relative_sgd_steps(self, ks: Sequence[int], k0: int) -> float:
        """Table 4: schedule compute relative to K-eta-fixed."""
        return float(np.sum(ks)) / (k0 * len(ks))
