"""ServingLoop — the global model as a live decode service (DESIGN.md §14).

Serve-while-training: federated personalisation rounds feed a production
decode path. The loop holds one jitted ``registry.decode_fn`` step (fixed
cache shapes -> exactly one compile), pulls ``GlobalModelStore.snapshot()``
— the exact tree clients hold, dequantised on demand by the downlink
codec's ``load_tree`` bracket — and hot-swaps it under the decode step
between rounds (sync trainer) or buffer applications (async engine).

Each ``tick`` replays one batch of a *deterministic* synthetic traffic
stream (prompt ids are a pure function of ``(seed, tick index)``, so a
resumed run serves the same queries), runs teacher-forced prefill + greedy
decode through the KV/SSM cache, and records into ``History``:

* ``serve_tokens_per_sec`` — decode throughput of the served model,
* ``serve_swap_us``        — snapshot + hot-swap latency (the cost of
  publishing a new version to the service),
* ``serve_staleness``      — how many store versions the *previously*
  served model had fallen behind by tick time. The sync trainer absorbs
  serve buckets immediately and ticks before the next dispatch commits, so
  this is <= 1; the async engine ticks right after each buffer apply.

Traffic streams are pluggable through ``TRAFFIC_REGISTRY``
(``register_traffic``); the ``synthetic`` builtin draws uniform prompt ids
from a counter-seeded rng.
"""
from __future__ import annotations

import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registries import TRAFFIC_REGISTRY, register_traffic
from repro.core.engine.model_store import GlobalModelStore
from repro.models import registry

PyTree = Any


def _synthetic_traffic(*, cfg, batch: int, prompt_len: int, seed: int = 0,
                       **kw):
    """Uniform prompt ids; each tick's batch is a pure function of
    ``(seed, tick)`` so the stream replays identically across resumes."""
    def prompts(tick: int) -> np.ndarray:
        rng = np.random.default_rng([int(seed), int(tick)])
        return rng.integers(0, cfg.vocab_size,
                            size=(batch, prompt_len)).astype(np.int32)
    return prompts


register_traffic("synthetic", _synthetic_traffic)


class ServingLoop:
    """Hot-swaps ``store.snapshot()`` under a jitted decode step and
    replays deterministic traffic against the served version."""

    def __init__(self, store: GlobalModelStore, cfg, *, batch: int = 2,
                 prompt_len: int = 4, tokens: int = 8,
                 moe_path: str = "dense", traffic: str = "synthetic",
                 seed: int = 0):
        if cfg.arch_type == "audio":
            raise ValueError(
                f"arch {cfg.name!r} is an audio encoder-decoder: its decode "
                f"cache needs per-query audio embeddings, which the "
                f"synthetic serving loop does not model")
        self.store = store
        self.cfg = cfg
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.tokens = int(tokens)
        self._step = jax.jit(registry.decode_fn(cfg, moe_path=moe_path))
        self._traffic = TRAFFIC_REGISTRY.get(traffic)(
            cfg=cfg, batch=self.batch, prompt_len=self.prompt_len, seed=seed)
        self.params: PyTree = None
        self.served_version = -1
        self.ticks = 0
        self.total_tokens = 0
        self.swap()

    # ------------------------------------------------------------------
    def swap(self) -> float:
        """Publish the store's current snapshot to the service; returns the
        swap latency in µs (snapshot + dequantise, materialised)."""
        t0 = time.perf_counter()
        version, tree = self.store.snapshot()
        tree = jax.block_until_ready(tree)
        us = (time.perf_counter() - t0) * 1e6
        self.params = tree
        self.served_version = version
        return us

    def decode(self, prompt_ids,
               params: Optional[PyTree] = None) -> Tuple[jax.Array, float]:
        """One traffic replay: teacher-forced prefill through the decode
        path, then greedy decode of ``self.tokens`` tokens. Returns the
        (batch, tokens) generated ids and the timed decode seconds (the
        prefill warms/loads the executable and is excluded, matching
        ``examples/serve_decode.py``)."""
        params = self.params if params is None else params
        prompt = jnp.asarray(prompt_ids)
        cache = registry.init_cache(params, self.cfg, prompt.shape[0],
                                    self.prompt_len + self.tokens)
        for pos in range(self.prompt_len):
            logits, cache = self._step(params, cache, prompt[:, pos],
                                       jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)
        out = []
        t0 = time.perf_counter()
        for i in range(self.tokens):
            logits, cache = self._step(params, cache, tok,
                                       jnp.int32(self.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return jnp.stack(out, axis=1), dt

    def tick(self, round_idx: int, history=None) -> float:
        """One serving tick at round/apply ``round_idx``: measure how stale
        the currently served version got, hot-swap the fresh snapshot, and
        replay one traffic batch against it. Returns tokens/sec."""
        staleness = self.store.version - self.served_version
        swap_us = self.swap()
        _, dt = self.decode(self._traffic(self.ticks))
        tps = self.batch * self.tokens / max(dt, 1e-9)
        self.ticks += 1
        self.total_tokens += self.batch * self.tokens
        if history is not None:
            history.serve_rounds.append(int(round_idx))
            history.serve_tokens_per_sec.append(float(tps))
            history.serve_swap_us.append(float(swap_us))
            history.serve_staleness.append(int(staleness))
        return tps
