from repro.core.serve.loop import ServingLoop

__all__ = ["ServingLoop"]
