# The paper's primary contribution: FedAvg with decaying local SGD steps.
from repro.core.fedavg import FedAvgTrainer, History, make_eval_fn, make_round_fn
from repro.core.loss_tracker import LossTracker, PlateauDetector
from repro.core.runtime_model import RoundCost, RuntimeModel
from repro.core.schedules import (DecayController, ETA_SCHEDULES, K_SCHEDULES,
                                  quantize_k, schedule_preview)
from repro.core import theory

__all__ = ["FedAvgTrainer", "History", "make_eval_fn", "make_round_fn",
           "LossTracker", "PlateauDetector", "RoundCost", "RuntimeModel",
           "DecayController", "ETA_SCHEDULES", "K_SCHEDULES", "quantize_k",
           "schedule_preview", "theory"]
