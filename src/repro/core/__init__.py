# The paper's primary contribution: FedAvg with decaying local SGD steps.
from repro.core import theory
from repro.core.engine import (RoundEngine, RoundScheduler, get_aggregator,
                               get_server_optimizer)
from repro.core.fedavg import (FedAvgTrainer, History, ReferenceRun,
                               make_eval_fn, make_round_fn,
                               run_reference_rounds)
from repro.core.loss_tracker import LossTracker, PlateauDetector
from repro.core.mem import engine_peak_mb, executable_peak_mb, trainer_peak_mb
from repro.core.runtime_model import RoundCost, RuntimeModel
from repro.core.schedules import (DecayController, ETA_SCHEDULES, K_SCHEDULES,
                                  quantize_k, schedule_preview)

__all__ = ["FedAvgTrainer", "History", "ReferenceRun", "make_eval_fn",
           "make_round_fn", "run_reference_rounds", "RoundEngine",
           "RoundScheduler",
           "get_aggregator", "get_server_optimizer",
           "LossTracker", "PlateauDetector", "RoundCost", "RuntimeModel",
           "DecayController", "ETA_SCHEDULES", "K_SCHEDULES", "quantize_k",
           "schedule_preview", "theory", "engine_peak_mb",
           "executable_peak_mb", "trainer_peak_mb"]
