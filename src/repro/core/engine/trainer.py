"""FedAvgTrainer on the layered round engine (DESIGN.md §6).

Drives: RoundScheduler (K-bucket plan) -> BatchPrefetcher (host tensors for
the upcoming bucket, built on a background thread) -> RoundEngine (one
jitted multi-round scan per bucket) -> DecayController feedback.

Synchronisation policy:
  * loss-free schedules (fixed/dsgd/rounds/cosine x fixed/rounds) never
    block mid-plan: bucket r's losses are materialised only after bucket
    r+1 has been dispatched, so host batch building, device compute and
    history accounting overlap;
  * error/step schedules sync at bucket boundaries only (bucket length
    ``fed.feedback_bucket_rounds``; the default 1 reproduces the seed
    per-round feedback loop exactly).

Evaluation happens at bucket boundaries; the scheduler cuts buckets at
``eval_every`` multiples so eval rounds match the seed loop exactly.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core.engine.backends.base import LINEAR_AGGREGATORS
from repro.core.engine.model_store import GlobalModelStore
from repro.core.engine.round import LossFn, RoundEngine
from repro.core.engine.sampling import make_sampler
from repro.core.engine.scheduler import Bucket, RoundScheduler
from repro.core.engine.transport import get_transport
from repro.core.runtime_model import RuntimeModel
from repro.core.schedules import DecayController
from repro.data import pipeline
from repro.data.synthetic import FederatedData

PyTree = Any


# ---------------------------------------------------------------------------
# history
# ---------------------------------------------------------------------------

@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    k: List[int] = field(default_factory=list)
    eta: List[float] = field(default_factory=list)
    wall_clock_s: List[float] = field(default_factory=list)   # cumulative, Eq. 5
    sgd_steps: List[int] = field(default_factory=list)        # cumulative
    uplink_mbit: List[float] = field(default_factory=list)    # cumulative wire
    downlink_mbit: List[float] = field(default_factory=list)  # cumulative wire
    train_loss: List[float] = field(default_factory=list)     # Eq. 15 round mean
    min_train_loss: List[float] = field(default_factory=list) # Fig. 1 metric
    val_rounds: List[int] = field(default_factory=list)
    val_error: List[float] = field(default_factory=list)
    max_val_acc: List[float] = field(default_factory=list)    # Fig. 2 metric
    # --- async buffered aggregation (DESIGN.md §13; empty for sync runs,
    # missing-field defaults keep pre-async checkpoints loadable) ---
    staleness: List[float] = field(default_factory=list)      # per-apply mean
    applied_updates: List[int] = field(default_factory=list)  # cumulative
    dropped_updates: List[int] = field(default_factory=list)  # cumulative
    # --- serve-while-training (DESIGN.md §14; empty unless a ServingLoop
    # is attached, missing-field defaults keep older checkpoints loadable) ---
    serve_rounds: List[int] = field(default_factory=list)     # tick round/apply
    serve_tokens_per_sec: List[float] = field(default_factory=list)
    serve_swap_us: List[float] = field(default_factory=list)  # snapshot swap
    serve_staleness: List[int] = field(default_factory=list)  # versions behind

    def as_dict(self) -> Dict[str, list]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, list]) -> "History":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - names)
        if unknown:
            # a checkpoint written by a different History schema: dropping
            # fields silently would hide drift from the operator
            warnings.warn(
                f"History.from_dict: ignoring unknown field(s) {unknown} "
                f"(checkpoint written by a different History schema?)",
                stacklevel=2)
        return cls(**{k: list(v) for k, v in d.items() if k in names})


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class FedAvgTrainer:
    def __init__(self, loss_fn: LossFn, init_params: PyTree,
                 data: FederatedData, fed: FedConfig,
                 runtime: RuntimeModel,
                 eval_fn: Optional[Callable[[PyTree], Dict[str, float]]] = None,
                 use_kernel_avg: Optional[bool] = None, backend=None,
                 sampler=None, registry=None, program_key=None):
        """``backend``: an ``engine.backends.ExecutionBackend`` deciding the
        execution geometry (default LocalBackend; pass a MeshBackend to run
        the same schedules/aggregators/servers GSPMD-sharded).

        ``sampler``: a ``ClientSampler`` instance overriding
        ``fed.sampler`` (default: resolve ``fed.sampler`` through the
        registry; ``uniform`` reproduces the historical stream exactly).

        ``registry`` / ``program_key``: a shared
        ``engine.round.ExecutableRegistry`` + the experiment's program
        fingerprint, forwarded to the RoundEngine for cross-experiment AOT
        executable reuse in fleet sweeps (DESIGN.md §12). Default: private
        registry, historical behaviour.

        ``use_kernel_avg`` is DEPRECATED: use ``fed.aggregator="kernel"``
        (it has been folded into aggregator resolution; the kwarg is a
        one-release shim)."""
        self.loss_fn = loss_fn
        # the store owns every piece of server-side model state (params,
        # server-optimizer state, transport EF, downlink ref/residual,
        # version, cost counters); trainer attributes below are properties
        # delegating to it (DESIGN.md §14)
        self.store = GlobalModelStore()
        self.params = init_params
        self.data = data
        self.fed = fed
        self.runtime = runtime
        self.eval_fn = eval_fn
        self.ctrl = DecayController(fed)
        aggregator = fed.aggregator
        if use_kernel_avg is not None:
            warnings.warn(
                "FedAvgTrainer(use_kernel_avg=...) is deprecated and will "
                "be removed next release; use FedConfig(aggregator='kernel') "
                "or register a custom aggregator instead.",
                DeprecationWarning, stacklevel=2)
            if use_kernel_avg:
                aggregator = "kernel"
        self.sampler = sampler if sampler is not None else make_sampler(fed)
        if (getattr(self.sampler, "needs_weighted_aggregation", False)
                and aggregator not in LINEAR_AGGREGATORS):
            # e.g. availability shortfall pads the cohort at weight 0;
            # median/trimmed_mean ignore weights and would aggregate the
            # padded offline clients as full participants
            raise ValueError(
                f"sampler {self.sampler.name!r} encodes participation in "
                f"the aggregation weights and needs a weight-respecting "
                f"aggregator {LINEAR_AGGREGATORS}, got {aggregator!r}")
        transport = get_transport(getattr(fed, "transport", "none"),
                                  topk_frac=getattr(fed, "topk_frac", 0.1))
        if (transport is not None and transport.error_feedback
                and self.sampler.stateful_cohort):
            # fixed cohort: slot j is the same client every round, so the
            # codec residual moves from one server-aggregate buffer to
            # per-client slots (DESIGN.md §9.3)
            transport = transport.with_ef_slots(fed.clients_per_round)
        self.engine = RoundEngine(loss_fn, aggregator=aggregator,
                                  trim_fraction=fed.trim_fraction,
                                  server=fed.server_optimizer,
                                  server_lr=fed.server_lr,
                                  backend=backend,
                                  transport=transport,
                                  topk_frac=getattr(fed, "topk_frac", 0.1),
                                  downlink=getattr(fed, "downlink", "none"),
                                  downlink_ref=getattr(fed, "downlink_ref",
                                                       "f32"),
                                  cohort_chunk=getattr(fed, "cohort_chunk",
                                                       None),
                                  registry=registry,
                                  program_key=program_key)
        self.engine.bind_store(self.store)
        self.server_state = self.engine.init_server_state(init_params)
        self.engine.init_transport_state(init_params)
        self.engine.init_downlink_state(init_params)
        if self.engine.transport is not None or \
                self.engine.downlink is not None:
            # charge the wire what the codecs ship — on a trainer-owned
            # copy (an injected RuntimeModel may be shared across trainers
            # with different transports); clone the straggler rng so the
            # copy owns its draw stream too
            import copy as _copy
            rt = _copy.copy(runtime)
            rt._rng = np.random.default_rng()
            rt._rng.bit_generator.state = runtime._rng.bit_generator.state
            if self.engine.transport is not None:
                rt.uplink_compression = \
                    self.engine.transport.compression_ratio(init_params)
            if self.engine.downlink is not None:
                rt.downlink_compression = \
                    self.engine.downlink.compression_ratio(init_params)
                # adaptive codec: per-level ratios so each round's wire
                # charge follows the level it actually shipped (§10.4)
                level_ratios = getattr(self.engine.downlink, "level_ratios",
                                       None)
                if level_ratios is not None:
                    rt.downlink_level_ratios = level_ratios(init_params)
            self.runtime = rt
        self.history = History()
        self._np_rng = np.random.default_rng(fed.seed)
        self._completed_rounds = 0
        # serve-while-training: ``api.build`` attaches a ServingLoop +
        # cadence when the spec asks for one; the trainer itself only
        # ticks it at bucket boundaries (DESIGN.md §14)
        self.serving = None
        self.serve_every = 0

    # ------------------------------------------------------------------
    # state delegation: the GlobalModelStore owns it, the historical
    # attribute names keep reading/writing it
    # ------------------------------------------------------------------
    params = property(lambda self: self.store.params,
                      lambda self, v: setattr(self.store, "params", v))
    server_state = property(
        lambda self: self.store.server_state,
        lambda self, v: setattr(self.store, "server_state", v))
    _wall = property(lambda self: self.store.wall,
                     lambda self, v: setattr(self.store, "wall", v))
    _steps = property(lambda self: self.store.steps,
                      lambda self, v: setattr(self.store, "steps", v))
    _up_mbit = property(lambda self: self.store.up_mbit,
                        lambda self, v: setattr(self.store, "up_mbit", v))
    _down_mbit = property(lambda self: self.store.down_mbit,
                          lambda self, v: setattr(self.store, "down_mbit", v))
    _min_loss = property(lambda self: self.store.min_loss,
                         lambda self, v: setattr(self.store, "min_loss", v))
    _max_acc = property(lambda self: self.store.max_acc,
                        lambda self, v: setattr(self.store, "max_acc", v))

    # ------------------------------------------------------------------
    @property
    def compile_count(self) -> int:
        return self.engine.compile_count

    @property
    def shared_count(self) -> int:
        """Executables adopted from a shared registry without compiling."""
        return self.engine.shared_count

    @property
    def dispatch_count(self) -> int:
        return self.engine.dispatch_count

    def run(self, rounds: Optional[int] = None, eval_every: int = 10,
            verbose: bool = False, resume: bool = False) -> History:
        """``resume=True`` continues a restored run (``restore_state``) from
        the first unexecuted round; the default replays the full schedule
        (repeated ``run()`` calls keep their historical warm-rerun
        semantics)."""
        rounds = rounds if rounds is not None else self.fed.rounds
        start = self._completed_rounds + 1 if resume else 1
        if start > rounds:
            return self.history
        sched = RoundScheduler(
            self.ctrl, self.fed, total_rounds=rounds,
            eval_every=eval_every if self.eval_fn is not None else None,
            serve_every=self.serve_every if self.serving is not None
            else None,
            start_round=start)
        if (self.serving is not None
                and self.serving.served_version != self.store.version):
            # a restored (or warm-rerun) store is ahead of the loop's
            # construction-time snapshot — re-swap so the first tick's
            # staleness measures this run, not the gap
            self.serving.swap()
        # the builder consumes the trainer's persistent rng so repeated
        # run() calls continue one sample stream (seed-loop semantics)
        # buckets are device_put with the backend's client sharding as soon
        # as they are built — on the prefetch thread, the H2D transfer
        # overlaps the previous bucket's device compute
        builder = pipeline.make_builder(
            self.data, self.fed.clients_per_round, self.fed.batch_size,
            self._np_rng,
            background=self.fed.prefetch and sched.loss_free,
            place_fn=self.engine.backend.place_bucket,
            sampler=self.sampler,
            chunk=getattr(self.fed, "cohort_chunk", None),
            place_slab_fn=self.engine.backend.place_slab)
        try:
            if sched.loss_free:
                self._run_pipelined(sched, builder, rounds, verbose)
            else:
                self._run_feedback(sched, builder, rounds, verbose)
        finally:
            builder.close()
        self._completed_rounds = rounds
        return self.history

    # ------------------------------------------------------------------
    def _dispatch(self, bucket: Bucket, bb: pipeline.BucketBatch):
        """Run one bucket on device; returns the (B, N) first-loss futures
        and the bucket's (B,) adaptive downlink levels (None without an
        adaptive codec) — captured immediately because the engine attribute
        is overwritten by the next pipelined dispatch."""
        pad = bucket.shape_rounds - len(bucket)
        etas = np.asarray(list(bucket.etas) + [bucket.etas[-1]] * pad,
                          np.float32)
        self.params, firsts, _lasts, self.server_state = \
            self.engine.run_bucket(self.params, bb.batches, bb.weights,
                                   etas, bb.active, self.server_state)
        levels = (self.engine.last_downlink_levels
                  if getattr(self.runtime, "downlink_level_ratios", None)
                  is not None else None)
        self.store.advance(len(bucket))   # params committed for B rounds
        return firsts, levels

    def _submit(self, builder, bucket: Bucket) -> None:
        """Announce a bucket to the builder: a whole K-bucket, or — under
        streaming cohorts (DESIGN.md §11) — the single round's slab
        stream."""
        if getattr(self.fed, "cohort_chunk", None):
            builder.submit_slabs(bucket.k, round_id=bucket.rounds[0])
        else:
            builder.submit(len(bucket), bucket.k, pad_to=bucket.shape_rounds,
                           rounds=bucket.rounds)

    def _pull_dispatch(self, bucket: Bucket, builder):
        if getattr(self.fed, "cohort_chunk", None):
            return self._dispatch_chunked(bucket, builder)
        return self._dispatch(bucket, builder.get())

    def _dispatch_chunked(self, bucket: Bucket, builder):
        """One streaming round (the scheduler forces 1-round buckets under
        chunking): pull the round's ceil(U/C) slabs off the builder and
        fold them through the engine's slab/finalize executables. No
        adaptive downlink levels — chunking rejects downlink codecs."""
        n = min(self.fed.clients_per_round, self.data.num_clients)
        c = min(max(int(self.fed.cohort_chunk), 1), n)
        n_slabs = -(-n // c)

        def slabs():
            for _ in range(n_slabs):
                yield builder.get()

        self.params, firsts, _lasts, self.server_state = \
            self.engine.run_round_chunked(self.params, slabs(),
                                          bucket.etas[0], self.server_state)
        self.store.advance(1)
        return firsts, None

    def _run_pipelined(self, sched: RoundScheduler, builder, rounds: int,
                       verbose: bool) -> None:
        plan = sched.plan()
        pending: Optional[Tuple[Bucket, jax.Array, Any]] = None
        nxt = next(plan, None)
        if nxt is not None:
            self._submit(builder, nxt)
        while nxt is not None:
            cur, nxt = nxt, next(plan, None)
            if nxt is not None:   # scheduler announces the upcoming K-bucket
                self._submit(builder, nxt)
            firsts, levels = self._pull_dispatch(cur, builder)
            if pending is not None:     # sync bucket r-1 while r computes
                self._absorb(*pending)
                pending = None
            if cur.eval_after or cur.serve_after:
                # serve buckets absorb immediately too: the serve tick in
                # _absorb must run before the *next* dispatch commits, which
                # is what bounds served-version staleness at 1 (§14)
                self._absorb(cur, firsts, levels)
                if cur.eval_after:
                    self._eval(cur.rounds[-1], verbose)
            else:
                pending = (cur, firsts, levels)
        if pending is not None:
            self._absorb(*pending)

    def _run_feedback(self, sched: RoundScheduler, builder, rounds: int,
                      verbose: bool) -> None:
        # plan() is lazy: each iteration consults the controller, which has
        # absorbed the previous bucket's losses by the time it is advanced
        for bucket in sched.plan():
            self._submit(builder, bucket)
            firsts, levels = self._pull_dispatch(bucket, builder)
            self._absorb(bucket, firsts, levels)  # boundary sync
            if bucket.eval_after:
                self._eval(bucket.rounds[-1], verbose)

    # ------------------------------------------------------------------
    def _absorb(self, bucket: Bucket, firsts: jax.Array,
                levels=None) -> None:
        """Materialise a finished bucket into controller + history state.

        ``levels``: the bucket's (B,) adaptive downlink levels — only
        supplied (by ``_dispatch``) when the runtime carries per-level
        ratios, so fixed-rate codecs keep the historical charge exactly."""
        losses = np.asarray(firsts)               # device sync
        lv = None if levels is None else np.asarray(levels)
        h = self.history
        for i, r in enumerate(bucket.rounds):
            round_loss = float(np.mean(losses[i]))
            self.ctrl.observe_round_losses(round_loss)
            cost = self.runtime.round_cost(
                bucket.k,
                downlink_level=None if lv is None else int(lv[i]))
            self._wall += cost.wall_clock_s
            self._steps += cost.sgd_steps
            self._up_mbit += cost.uplink_mbit
            self._down_mbit += cost.downlink_mbit
            self.store.serve_queries += cost.serve_queries
            self._min_loss = min(self._min_loss, round_loss)
            h.rounds.append(r)
            h.k.append(bucket.k)
            h.eta.append(bucket.etas[i])
            h.wall_clock_s.append(self._wall)
            h.sgd_steps.append(self._steps)
            h.uplink_mbit.append(self._up_mbit)
            h.downlink_mbit.append(self._down_mbit)
            h.train_loss.append(round_loss)
            h.min_train_loss.append(self._min_loss)
            if (self.serving is not None and self.serve_every
                    and r % self.serve_every == 0):
                self.serving.tick(r, h)

    # ------------------------------------------------------------------
    # full-state checkpointing (DESIGN.md §8: transport/EF state included)
    # ------------------------------------------------------------------
    def save_state(self, path: str,
                   extra_meta: Optional[Dict[str, Any]] = None) -> None:
        """Checkpoint everything a bitwise-identical continuation needs:
        params, server-optimizer state, transport error-feedback state, the
        numpy rng stream, controller feedback state, history and the
        simulated-cost counters. Restore with ``restore_state`` and continue
        via ``run(rounds, resume=True)``.

        ``extra_meta``: JSON-serializable entries merged into ``meta.json``
        (``FederatedExperiment.save`` embeds the ExperimentSpec here so a
        checkpoint alone rebuilds the exact trainer)."""
        from repro.checkpoint import save_checkpoint
        sd = self.store.state_dict()
        meta = {
            **(extra_meta or {}),
            "completed_rounds": self._completed_rounds,
            "history": self.history.as_dict(),
            "rng": self._np_rng.bit_generator.state,
            # straggler-model draw stream (heterogeneity > 0 consumes it
            # every round_cost call)
            "runtime_rng": self.runtime._rng.bit_generator.state,
            "wall": self._wall,
            **sd["meta"],
            "ctrl": self.ctrl.state_dict(),
        }
        save_checkpoint(path, sd["tree"], meta=meta)

    def restore_state(self, path: str) -> None:
        """Inverse of ``save_state`` on a trainer built with the same
        configuration (templates for every state tree come from the live
        trainer)."""
        # the q8 legacy-key fallback (pre-q8 checkpoint into a
        # ref_store="q8" trainer, DESIGN.md §10.3) lives in the store now
        tree, meta = self.store.load_checkpoint_tree(path)
        self.store.restore_tree(tree)
        self._completed_rounds = int(meta["completed_rounds"])
        self.history = History.from_dict(meta["history"])
        h = self.history
        if len(h.downlink_mbit) < len(h.rounds):
            # pre-downlink checkpoint: backfill the new cumulative series
            # (no broadcast bytes were charged then) so the per-round lists
            # stay index-aligned for CSV writers/plots
            h.downlink_mbit = ([0.0] * (len(h.rounds)
                                        - len(h.downlink_mbit))
                               + h.downlink_mbit)
        self._np_rng.bit_generator.state = meta["rng"]
        if "runtime_rng" in meta:
            self.runtime._rng.bit_generator.state = meta["runtime_rng"]
        self._wall = float(meta["wall"])
        # pre-PR-10 meta has no store_version: fall back to the round count
        self.store.load_counters_meta(
            meta, default_version=self._completed_rounds)
        self.ctrl.load_state_dict(meta["ctrl"])

    def _eval(self, r: int, verbose: bool) -> None:
        metrics = self.eval_fn(self.params)
        err = metrics.get("error", 1.0 - metrics.get("acc", 0.0))
        self.ctrl.observe_validation(err)
        self._max_acc = max(self._max_acc, metrics.get("acc", 0.0))
        h = self.history
        h.val_rounds.append(r)
        h.val_error.append(err)
        h.max_val_acc.append(self._max_acc)
        if verbose:
            print(f"round {r:5d} K={h.k[-1]:3d} eta={h.eta[-1]:.4f} "
                  f"loss={h.train_loss[-1]:.4f} val_err={err:.4f} "
                  f"W={self._wall:.1f}s steps={self._steps}")


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def make_eval_fn(loss_fn: LossFn, data: FederatedData, batch_size: int = 128):
    """Validation accuracy/error over the global validation split.

    Per-batch means are weighted by batch size so the ragged tail batch
    (``val_batches`` keeps the remainder) contributes exactly its share.
    """
    batches = pipeline.val_batches(data, batch_size)

    @jax.jit
    def eval_batch(params, batch):
        loss, metrics = loss_fn(params, batch)
        return loss, metrics.get("acc", jax.numpy.zeros(()))

    def eval_fn(params) -> Dict[str, float]:
        loss_sum = acc_sum = 0.0
        n_tot = 0
        for b in batches:
            n = len(b["y"])
            l, a = eval_batch(params,
                              {k: jax.numpy.asarray(v) for k, v in b.items()})
            loss_sum += float(l) * n
            acc_sum += float(a) * n
            n_tot += n
        acc = acc_sum / max(n_tot, 1)
        return {"loss": loss_sum / max(n_tot, 1), "acc": acc,
                "error": 1.0 - acc}

    return eval_fn
