"""ClientUpdate — the K-step local-SGD scan (Algorithm 1, lines 5-9).

This is the single source of truth for a client's local update; both the
single-process engine (`engine.round`) and the mesh-level strategies
(`distributed.strategies`) build on it, so the paper's local-SGD semantics
live in exactly one place (DESIGN.md §6.1).

The update is stateless plain SGD per the paper: clients carry no optimizer
state between rounds (the server may — see `engine.server`).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]


class ClientResult(NamedTuple):
    """One client's round output."""
    params: PyTree          # x_{r,K}^c — params after K local steps
    first_loss: jnp.ndarray  # f_c(x_r, xi_{c,0}) — Eq. 15 feedback signal
    last_loss: jnp.ndarray   # f_c(x_{r,K-1}, xi_{c,K-1})


def client_update(loss_fn: LossFn, params: PyTree,
                  client_batches: Dict[str, jnp.ndarray],
                  eta: jnp.ndarray,
                  reconstruct: Any = None) -> ClientResult:
    """K steps of SGD from the round-start params.

    Leaves of ``client_batches`` have leading K axis; ``eta`` is a scalar.
    Updates are cast back to each weight's dtype so mixed-precision params
    stay in their storage dtype across the scan carry.

    ``reconstruct``: optional callable applied to ``params`` before the
    first step — the downlink lazy decode (DESIGN.md §10): ``params`` is
    then the (ref, payload) broadcast bundle and the client reconstructs
    its own round-start model inside its own trace, so the engine never
    materialises the decoded f32 tree as a separate round input.
    """
    if reconstruct is not None:
        params = reconstruct(params)

    def step(p, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        p = jax.tree.map(lambda w, g: (w - eta * g).astype(w.dtype), p, grads)
        return p, loss

    final, losses = jax.lax.scan(step, params, client_batches)
    return ClientResult(final, losses[0], losses[-1])


def make_client_update(loss_fn: LossFn, reconstruct: Any = None):
    """Bind ``loss_fn`` (and the optional downlink ``reconstruct`` hook):
    returns update(params, batches, eta) -> ClientResult."""
    def update(params, client_batches, eta):
        return client_update(loss_fn, params, client_batches, eta,
                             reconstruct)

    return update
