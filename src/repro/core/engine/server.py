"""ServerOptimizer — the server-side step applied to the aggregated model.

Protocol (functional, jit-friendly):

    init(params)                                  -> state
    step(params, aggregate, state, server_lr)     -> (new_params, state)

``aggregate`` is the output of the round's Aggregator. FedOpt-style servers
(Reddi et al. '21) treat the *pseudo-gradient* ``delta = params - aggregate``
as a gradient and run a stateful first-order method on it; plain FedAvg is
the stateless special case ``params + server_lr * (aggregate - params)``
(server_lr=1 recovers Algorithm 1 line 11 exactly). See DESIGN.md §6.3.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.api.registries import (SERVER_OPTIMIZER_REGISTRY,
                                  register_server_optimizer)

PyTree = Any

SERVER_OPTIMIZERS = ("avg", "fedadam", "fedavgm", "fedyogi")   # builtins


class ServerOptimizer(NamedTuple):
    init: Callable[[PyTree], Any]
    step: Callable[[PyTree, PyTree, Any, float], Tuple[PyTree, Any]]


def _avg() -> ServerOptimizer:
    def init(params):
        return ()

    def step(params, aggregate, state, server_lr):
        new = jax.tree.map(
            lambda p, a: (p + server_lr * (a - p)).astype(p.dtype),
            params, aggregate)
        return new, state

    return ServerOptimizer(init, step)


def _from_optim(pair) -> ServerOptimizer:
    opt_init, opt_update = pair

    def init(params):
        return opt_init(params)

    def step(params, aggregate, state, server_lr):
        delta = optim.tree_sub(params, aggregate)   # pseudo-gradient
        updates, state = opt_update(delta, state, params, server_lr)
        return optim.apply_updates(params, updates), state

    return ServerOptimizer(init, step)


def get_server_optimizer(name) -> ServerOptimizer:
    """Resolve through the plugin registry (did-you-mean on unknown names);
    a ``ServerOptimizer`` instance passes through."""
    if isinstance(name, ServerOptimizer):
        return name
    return SERVER_OPTIMIZER_REGISTRY.get(name)()


# builtin registrations — factory signature: f(**kw) -> ServerOptimizer
register_server_optimizer("avg", lambda **kw: _avg())
register_server_optimizer("fedadam",
                          lambda **kw: _from_optim(optim.fedadam_server()))
register_server_optimizer("fedavgm",
                          lambda **kw: _from_optim(optim.fedavgm_server()))
register_server_optimizer("fedyogi",
                          lambda **kw: _from_optim(optim.fedyogi_server()))
