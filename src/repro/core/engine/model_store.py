"""GlobalModelStore — the one owner of server-side model state.

FedAvg's server is, structurally, a model-state owner that alternates
broadcast and aggregate.  Before this module that state — ``params``, the
broadcast-side ``params_ref`` + downlink EF residual (the ``downlink_state``
dict maintained by the PR-5/6 state machine), the server-optimizer state and
the cumulative cost counters — was threaded ad-hoc and *duplicated* between
``FedAvgTrainer`` and ``AsyncBufferedEngine`` (two parallel
``save_state``/``restore_state`` bodies).  Both engines now delegate to a
single :class:`GlobalModelStore`:

* every broadcast-side access is bracketed through the downlink codec's
  ``store_tree``/``load_tree`` pair, so the q8 ref-store path (``
  transport.ref_store="q8"``) keeps exactly one quantised copy server-side;
* a monotone ``version`` counter advances once per committed round (sync)
  or buffer application (async);
* :meth:`snapshot` returns ``(version, params_ref)`` — the exact tree
  clients hold, dequantised on demand — without locking: it reads two
  attributes and runs at most one ``tree_map`` of elementwise dequantise
  ops, so a serving loop can call it mid-round (DESIGN.md §14);
* checkpoint payloads are thin wrappers over :meth:`state_dict`, with the
  legacy key layout (``params``/``server``/``transport``/``downlink`` +
  flat counter meta) preserved so pre-PR-10 checkpoints restore bitwise.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint

PyTree = Any


def as_spec_tree(tree: PyTree) -> PyTree:
    """Shape/dtype template of ``tree`` (the ``like`` argument of
    ``load_checkpoint``) without copying any data."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree)


class GlobalModelStore:
    """Versioned owner of the server-side model state shared by both
    engines.  Host-side only: holding state here (vs on the engine) never
    changes a traced program, so AOT executable keys are untouched by the
    extraction (asserted in tests, as in PRs 5/8/9)."""

    def __init__(self, params: PyTree = None, downlink=None):
        self.params: PyTree = params
        self.server_state: Any = None
        self.transport_state: Any = None
        self.downlink_state: Any = None
        self.downlink = downlink          # DownlinkCodec | None
        self.version: int = 0
        # cumulative simulated-cost counters (legacy flat meta keys)
        self.wall: float = 0.0
        self.steps: int = 0
        self.up_mbit: float = 0.0
        self.down_mbit: float = 0.0
        self.min_loss: float = float("inf")
        self.max_acc: float = 0.0
        self.serve_queries: float = 0.0

    # -- version ----------------------------------------------------------
    def advance(self, n: int = 1) -> int:
        """Bump the monotone version counter by ``n`` committed rounds /
        buffer applications and return the new version."""
        self.version += int(n)
        return self.version

    # -- lock-free serving snapshot ---------------------------------------
    def snapshot(self) -> Tuple[int, PyTree]:
        """``(version, params_ref)`` — the exact tree clients hold.

        With a downlink codec the broadcast reference (``state["ref"]``,
        maintained bitwise by the downlink state machine: after round t it
        stores exactly the tree clients reconstructed during t) is loaded
        back through the codec's own ``load_tree`` bracket — under
        ``ref_store="q8"`` that is the coherent dequantised view both the
        server and every client use as the next reconstruction base.
        Without one, clients hold ``params`` itself."""
        version = self.version
        dl, state = self.downlink, self.downlink_state
        if dl is not None and state is not None:
            return version, dl.load_tree(state["ref"], like=self.params)
        return version, self.params

    # -- checkpoint payloads (legacy key layout) --------------------------
    def checkpoint_tree(self) -> Dict[str, PyTree]:
        """The store-owned array tree, under the pre-PR-10 key names.  A
        ``None``/``()`` entry contributes no leaves, so the async engine
        (which never has downlink state) emits byte-identical ``arrays.npz``
        payloads with or without the ``downlink`` key."""
        return {"params": self.params, "server": self.server_state,
                "transport": self.transport_state,
                "downlink": self.downlink_state}

    def counters_meta(self) -> Dict[str, Any]:
        """Flat counter meta, legacy keys + the new ``store_version``."""
        return {"steps": self.steps, "up_mbit": self.up_mbit,
                "down_mbit": self.down_mbit, "min_loss": self.min_loss,
                "max_acc": self.max_acc, "serve_queries": self.serve_queries,
                "store_version": self.version}

    def state_dict(self) -> Dict[str, Any]:
        return {"tree": self.checkpoint_tree(), "meta": self.counters_meta()}

    def load_counters_meta(self, meta: Dict[str, Any],
                           default_version: int) -> None:
        """Restore the counters from checkpoint meta.  Pre-PR-10 meta has
        no ``store_version`` — fall back to the engine's round/application
        count (``default_version``), which is what the counter would have
        read had the store existed when the checkpoint was written."""
        self.steps = int(meta["steps"])
        self.up_mbit = float(meta["up_mbit"])
        # pre-PR-5 checkpoints have no downlink accounting
        self.down_mbit = float(meta.get("down_mbit", 0.0))
        self.min_loss = float(meta["min_loss"])
        self.max_acc = float(meta["max_acc"])
        self.serve_queries = float(meta.get("serve_queries", 0.0))
        self.version = int(meta.get("store_version", default_version))

    # -- checkpoint IO ----------------------------------------------------
    def load_checkpoint_tree(self, path,
                             extra_like: Optional[Dict[str, PyTree]] = None,
                             ) -> Tuple[Dict[str, PyTree], Dict[str, Any]]:
        """Load the store-owned tree (plus engine extras such as the async
        buffer/inflight slabs) from ``path``, templated on the *current*
        store layout.

        Legacy-key fallback: checkpoints written before ``ref_store="q8"``
        (or by an f32-ref run) store the downlink ref/residual as f32
        trees under the same keys.  When the current run wants q8, the
        load raises ``KeyError`` on the missing q8 sub-keys — reload
        against f32 templates and re-bracket through ``store_tree`` so the
        resumed run still holds exactly one quantised copy."""
        like = as_spec_tree({**self.checkpoint_tree(), **(extra_like or {})})
        try:
            return load_checkpoint(path, like)
        except KeyError:
            dl = self.downlink
            if dl is None or dl.ref_store == "f32":
                raise
            f32 = jax.tree.map(
                lambda p: jnp.zeros(np.shape(p), jnp.float32), self.params)
            like["downlink"] = as_spec_tree(
                {"ref": self.params,
                 "res": f32 if dl.error_feedback else ()})
            tree, meta = load_checkpoint(path, like)
            d = tree["downlink"]
            tree["downlink"] = {
                "ref": dl.store_tree(d["ref"]),
                "res": (dl.store_tree(d["res"]) if dl.error_feedback
                        else ())}
            return tree, meta

    def restore_tree(self, tree: Dict[str, PyTree], *,
                     place_params: Optional[Callable[[PyTree], PyTree]] = None,
                     place: Optional[Callable[[PyTree], PyTree]] = None,
                     ) -> None:
        """Adopt a loaded checkpoint tree.  ``place_params``/``place`` let
        an engine re-place arrays on its backend (the async engine shards
        params via ``backend.place_params`` and devices the rest)."""
        pp = place_params if place_params is not None else (lambda t: t)
        pl = place if place is not None else (lambda t: t)
        self.params = pp(tree["params"])
        self.server_state = pl(tree["server"])
        self.transport_state = pl(tree["transport"])
        self.downlink_state = pl(tree["downlink"])
