"""Round execution: single-round core + K-bucketed multi-round scan.

Layering (DESIGN.md §6):

    ClientUpdate (engine.client)   — K-step local SGD, vmapped over clients
    Aggregator   (engine.aggregators) — client-stack -> aggregate
    ServerOptimizer (engine.server)   — aggregate -> next global params

``RoundEngine`` composes the three and executes *buckets*: consecutive
rounds sharing one quantized K, run as a single jitted ``lax.scan`` over the
round axis. XLA compiles one executable per distinct ``(K, bucket_shape)``
pair, so with K snapped to the geometric grid (``quantize_k``) the compile
count is bounded by the grid size — instead of one compile per distinct raw
K_r and one dispatch per round.

Buckets shorter than the executable shape are padded by repeating the last
round's batches with ``active=False``; inactive rounds pass params and
server state through a ``jnp.where`` select, which is bitwise transparent,
so padding never perturbs training state.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.client import make_client_update
from repro.core.engine.server import ServerOptimizer, get_server_optimizer

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]


def make_round_core(loss_fn: LossFn, aggregator: Aggregator,
                    server: ServerOptimizer, server_lr: float):
    """round_core(params, batches{(N,K,b,...)}, weights(N,), eta, state)
    -> (new_params, first_losses (N,), last_losses (N,), state)."""
    client = make_client_update(loss_fn)

    def round_core(params, batches, weights, eta, server_state):
        client_params, first_losses, last_losses = jax.vmap(
            client, in_axes=(None, 0, None))(params, batches, eta)
        aggregate = aggregator(client_params, weights)
        new_params, server_state = server.step(params, aggregate,
                                               server_state, server_lr)
        return new_params, first_losses, last_losses, server_state

    return round_core


def make_bucket_fn(round_core):
    """Multi-round scan over a K-bucket.

    bucket_fn(params, batches{(B,N,K,b,...)}, weights(B,N), etas(B,),
              active(B,) bool, server_state)
        -> (new_params, first_losses (B,N), last_losses (B,N), server_state)
    """
    def bucket_fn(params, batches, weights, etas, active, server_state):
        def body(carry, xs):
            params, state = carry
            b, w, eta, act = xs
            new_p, first, last, new_s = round_core(params, b, w, eta, state)
            new_p = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_p, params)
            new_s = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_s, state)
            return (new_p, new_s), (first, last)

        (params, server_state), (firsts, lasts) = jax.lax.scan(
            body, (params, server_state), (batches, weights, etas, active))
        return params, firsts, lasts, server_state

    return bucket_fn


class RoundEngine:
    """Jit-compiled executor for round buckets with a bounded compile cache."""

    def __init__(self, loss_fn: LossFn, *, aggregator: str = "mean",
                 trim_fraction: float = 0.1, server: str = "avg",
                 server_lr: float = 1.0):
        self.server = get_server_optimizer(server)
        self.round_core = make_round_core(
            loss_fn, get_aggregator(aggregator, trim_fraction=trim_fraction),
            self.server, server_lr)
        self._bucket_fn = jax.jit(make_bucket_fn(self.round_core))
        self._shape_keys = set()

    def init_server_state(self, params: PyTree) -> Any:
        return self.server.init(params)

    def run_bucket(self, params, batches, weights, etas, active, server_state
                   ) -> Tuple[PyTree, jnp.ndarray, jnp.ndarray, Any]:
        """batches leaves (B, N, K, b, ...); weights (B, N); etas/active (B,)."""
        lead = next(iter(batches.values())).shape[:3]   # (B, N, K)
        self._shape_keys.add(lead)
        return self._bucket_fn(params,
                               {k: jnp.asarray(v) for k, v in batches.items()},
                               jnp.asarray(weights, jnp.float32),
                               jnp.asarray(etas, jnp.float32),
                               jnp.asarray(active, bool), server_state)

    @property
    def compile_count(self) -> int:
        """Number of distinct bucket executables built so far."""
        try:
            return int(self._bucket_fn._cache_size())
        except Exception:
            return len(self._shape_keys)


def make_round_fn(loss_fn: LossFn, *, server: str = "avg",
                  server_lr: float = 1.0, use_kernel_avg: bool = False):
    """Seed-compatible single-round builder (one jitted FedAvg round).

    round_fn(params, batches{(N,K,b,...)}, weights (N,), eta, server_state)
        -> (new_params, first_losses (N,), mean_last_loss, server_state)

    Returns ``(round_fn, srv_init)`` where ``srv_init`` is None for the
    stateless ``avg`` server (its state is ``()``), matching the historical
    ``make_round_fn`` contract that `tests` and benchmarks rely on.
    """
    srv = get_server_optimizer(server)
    core = make_round_core(
        loss_fn, get_aggregator("kernel" if use_kernel_avg else "mean"),
        srv, server_lr)

    def round_fn(params, batches, weights, eta, server_state):
        new_params, first_losses, last_losses, server_state = core(
            params, batches, weights, eta, server_state)
        return new_params, first_losses, jnp.mean(last_losses), server_state

    srv_init = None if server == "avg" else srv.init
    return jax.jit(round_fn), srv_init
