"""Round execution: single-round core + K-bucketed multi-round scan.

Layering (DESIGN.md §6-§7):

    ClientUpdate (engine.client)   — K-step local SGD, vmapped over clients
    Aggregator   (engine.aggregators) — client-stack -> aggregate
    ServerOptimizer (engine.server)   — aggregate -> next global params
    ExecutionBackend (engine.backends) — where/how the fan-out executes

``RoundEngine`` asks its backend for the round core (LocalBackend: plain
vmap; MeshBackend: GSPMD-sharded vmap or grouped sequential scan) and
executes *buckets*: consecutive rounds sharing one quantized K, run as a
single multi-round ``lax.scan`` over the round axis. Each distinct input
signature (shapes + dtypes of params/batches/weights/etas/active/state) is
AOT-lowered and compiled exactly once into an explicit executable registry,
so with K snapped to the geometric grid (``quantize_k``) the compile count
is bounded by the grid size — and ``compile_count`` reports the registry
size exactly instead of probing jit-internal caches.

Buckets shorter than the executable shape are padded by repeating the last
round's batches with ``active=False``; inactive rounds pass params and
server state through a ``jnp.where`` select, which is bitwise transparent,
so padding never perturbs training state.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.backends.base import ExecutionBackend
from repro.core.engine.backends.local import (LocalBackend,
                                              make_parallel_round_core)
from repro.core.engine.server import ServerOptimizer, get_server_optimizer

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]


def make_round_core(loss_fn: LossFn, aggregator: Aggregator,
                    server: ServerOptimizer, server_lr: float):
    """round_core(params, batches{(N,K,b,...)}, weights(N,), eta, state)
    -> (new_params, first_losses (N,), last_losses (N,), state)."""
    return make_parallel_round_core(loss_fn, aggregator, server, server_lr)


def make_bucket_fn(round_core):
    """Multi-round scan over a K-bucket.

    bucket_fn(params, batches{(B,N,K,b,...)}, weights(B,N), etas(B,),
              active(B,) bool, server_state)
        -> (new_params, first_losses (B,N), last_losses (B,N), server_state)
    """
    def bucket_fn(params, batches, weights, etas, active, server_state):
        def body(carry, xs):
            params, state = carry
            b, w, eta, act = xs
            new_p, first, last, new_s = round_core(params, b, w, eta, state)
            new_p = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_p, params)
            new_s = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_s, state)
            return (new_p, new_s), (first, last)

        (params, server_state), (firsts, lasts) = jax.lax.scan(
            body, (params, server_state), (batches, weights, etas, active))
        return params, firsts, lasts, server_state

    return bucket_fn


def _signature(args) -> Tuple:
    """Hashable (treedef, leaf shapes/dtypes) key for the AOT registry."""
    leaves, treedef = jax.tree.flatten(args)
    return treedef, tuple((tuple(l.shape), jnp.result_type(l).name)
                          for l in leaves)


class RoundEngine:
    """Bucket executor with an explicit per-signature executable registry.

    The backend decides execution geometry and placement; the engine owns
    compilation: ``run_bucket`` looks the placed arguments' signature up in
    the registry and AOT-compiles (``jit(...).lower(...).compile()``) on
    miss — one executable per distinct signature, counted exactly by
    ``compile_count`` (no reliance on private jit cache probes).
    """

    def __init__(self, loss_fn: LossFn, *, aggregator: str = "mean",
                 trim_fraction: float = 0.1, server: str = "avg",
                 server_lr: float = 1.0,
                 backend: Optional[ExecutionBackend] = None):
        self.backend = backend if backend is not None else LocalBackend()
        self.server = get_server_optimizer(server)
        self.round_core = self.backend.make_round_core(
            loss_fn, aggregator=aggregator, trim_fraction=trim_fraction,
            server=self.server, server_lr=server_lr)
        self._jitted = jax.jit(make_bucket_fn(self.round_core))
        self._executables: Dict[Tuple, Any] = {}
        self.dispatch_count = 0

    def init_server_state(self, params: PyTree) -> Any:
        return self.server.init(params)

    def run_bucket(self, params, batches, weights, etas, active, server_state
                   ) -> Tuple[PyTree, jnp.ndarray, jnp.ndarray, Any]:
        """batches leaves (B, N, K, b, ...); weights (B, N); etas/active (B,).

        Inputs may be host (numpy) or already-placed device arrays — the
        backend's placement hooks are idempotent, so prefetched buckets that
        were ``device_put`` on the build thread pass through untouched.
        """
        be = self.backend
        params = be.place_params(params)
        batches = be.place_batches(batches)
        weights = be.place_weights(weights)
        etas, active = be.place_scalars(etas, active)
        server_state = jax.tree.map(jnp.asarray, server_state)
        args = (params, batches, weights, etas, active, server_state)
        key = _signature(args)
        exe = self._executables.get(key)
        if exe is None:
            exe = self._jitted.lower(*args).compile()
            self._executables[key] = exe
        self.dispatch_count += 1
        return exe(*args)

    @property
    def compile_count(self) -> int:
        """Number of distinct bucket executables built so far (exact)."""
        return len(self._executables)


def make_round_fn(loss_fn: LossFn, *, server: str = "avg",
                  server_lr: float = 1.0, use_kernel_avg: bool = False):
    """Seed-compatible single-round builder (one jitted FedAvg round).

    round_fn(params, batches{(N,K,b,...)}, weights (N,), eta, server_state)
        -> (new_params, first_losses (N,), mean_last_loss, server_state)

    Returns ``(round_fn, srv_init)`` where ``srv_init`` is None for the
    stateless ``avg`` server (its state is ``()``), matching the historical
    ``make_round_fn`` contract that `tests` and benchmarks rely on.
    """
    srv = get_server_optimizer(server)
    core = make_round_core(
        loss_fn, get_aggregator("kernel" if use_kernel_avg else "mean"),
        srv, server_lr)

    def round_fn(params, batches, weights, eta, server_state):
        new_params, first_losses, last_losses, server_state = core(
            params, batches, weights, eta, server_state)
        return new_params, first_losses, jnp.mean(last_losses), server_state

    srv_init = None if server == "avg" else srv.init
    return jax.jit(round_fn), srv_init
