"""Round execution: single-round core + K-bucketed multi-round scan.

Layering (DESIGN.md §6-§7):

    ClientUpdate (engine.client)   — K-step local SGD, vmapped over clients
    Aggregator   (engine.aggregators) — client-stack -> aggregate
    ServerOptimizer (engine.server)   — aggregate -> next global params
    ExecutionBackend (engine.backends) — where/how the fan-out executes

``RoundEngine`` asks its backend for the round core (LocalBackend: plain
vmap; MeshBackend: GSPMD-sharded vmap or grouped sequential scan) and
executes *buckets*: consecutive rounds sharing one quantized K, run as a
single multi-round ``lax.scan`` over the round axis. Each distinct input
signature (shapes + dtypes of params/batches/weights/etas/active/state) is
AOT-lowered and compiled exactly once into an explicit executable registry,
so with K snapped to the geometric grid (``quantize_k``) the compile count
is bounded by the grid size — and ``compile_count`` reports the registry
size exactly instead of probing jit-internal caches.

Buckets shorter than the executable shape are padded by repeating the last
round's batches with ``active=False``; inactive rounds pass params and
server state through a ``jnp.where`` select, which is bitwise transparent,
so padding never perturbs training state.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.backends.base import (ExecutionBackend,
                                             LINEAR_AGGREGATORS)
from repro.core.engine.backends.local import (LocalBackend,
                                              make_parallel_round_core)
from repro.core.engine.model_store import GlobalModelStore
from repro.core.engine.server import ServerOptimizer, get_server_optimizer
from repro.core.engine.transport import get_downlink, get_transport

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]


def make_round_core(loss_fn: LossFn, aggregator: Aggregator,
                    server: ServerOptimizer, server_lr: float):
    """round_core(params, batches{(N,K,b,...)}, weights(N,), eta, state)
    -> (new_params, first_losses (N,), last_losses (N,), state)."""
    return make_parallel_round_core(loss_fn, aggregator, server, server_lr)


def make_bucket_fn(round_core):
    """Multi-round scan over a K-bucket.

    bucket_fn(params, batches{(B,N,K,b,...)}, weights(B,N), etas(B,),
              active(B,) bool, server_state)
        -> (new_params, first_losses (B,N), last_losses (B,N), server_state)
    """
    def bucket_fn(params, batches, weights, etas, active, server_state):
        def body(carry, xs):
            params, state = carry
            b, w, eta, act = xs
            new_p, first, last, new_s = round_core(params, b, w, eta, state)
            new_p = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_p, params)
            new_s = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                 new_s, state)
            return (new_p, new_s), (first, last)

        (params, server_state), (firsts, lasts) = jax.lax.scan(
            body, (params, server_state), (batches, weights, etas, active))
        return params, firsts, lasts, server_state

    return bucket_fn


def make_transport_bucket_fn(round_core):
    """Multi-round scan for a transport-threaded core (DESIGN.md §8): the
    carry additionally holds the codec's error-feedback state, masked on
    padding rounds with the same bitwise-transparent ``jnp.where`` select
    as params and server state.

    bucket_fn(params, batches, weights, etas, active, server_state, t_state)
        -> (new_params, first_losses, last_losses, server_state, t_state)
    """
    def bucket_fn(params, batches, weights, etas, active, server_state,
                  t_state):
        def body(carry, xs):
            params, state, tstate = carry
            b, w, eta, act = xs
            new_p, first, last, new_s, new_t = round_core(
                params, b, w, eta, state, tstate)
            sel = lambda n, o: jnp.where(act, n, o)
            new_p = jax.tree.map(sel, new_p, params)
            new_s = jax.tree.map(sel, new_s, state)
            new_t = jax.tree.map(sel, new_t, tstate)
            return (new_p, new_s, new_t), (first, last)

        (params, server_state, t_state), (firsts, lasts) = jax.lax.scan(
            body, (params, server_state, t_state),
            (batches, weights, etas, active))
        return params, firsts, lasts, server_state, t_state

    return bucket_fn


def make_downlink_bucket_fn(round_core):
    """Multi-round scan for a downlink-fused core (DESIGN.md §10): the
    carry's trailing slot is the downlink state (or the ``(uplink,
    downlink)`` pair) and the core emits one extra per-round output — the
    adaptive codec level — stacked as a ``(B,)`` int32 ys alongside the
    losses. Padding rounds mask the state with the bitwise-transparent
    ``jnp.where`` select and report level -1 (the "not a real round"
    sentinel the trainer skips when charging the wire).

    bucket_fn(params, batches, weights, etas, active, server_state, extra)
        -> (new_params, first_losses, last_losses, server_state, extra,
            levels (B,) int32)
    """
    def bucket_fn(params, batches, weights, etas, active, server_state,
                  extra):
        def body(carry, xs):
            params, state, ex = carry
            b, w, eta, act = xs
            new_p, first, last, new_s, new_e, level = round_core(
                params, b, w, eta, state, ex)
            sel = lambda n, o: jnp.where(act, n, o)
            new_p = jax.tree.map(sel, new_p, params)
            new_s = jax.tree.map(sel, new_s, state)
            new_e = jax.tree.map(sel, new_e, ex)
            level = jnp.where(act, level, jnp.int32(-1))
            return (new_p, new_s, new_e), (first, last, level)

        (params, server_state, extra), (firsts, lasts, levels) = jax.lax.scan(
            body, (params, server_state, extra),
            (batches, weights, etas, active))
        return params, firsts, lasts, server_state, extra, levels

    return bucket_fn


def _signature(args) -> Tuple:
    """Hashable (treedef, leaf shapes/dtypes) key for the AOT registry."""
    leaves, treedef = jax.tree.flatten(args)
    return treedef, tuple((tuple(l.shape), jnp.result_type(l).name)
                          for l in leaves)


class ExecutableRegistry:
    """Process-level AOT executable cache, shareable across experiments.

    Entries are keyed by the engine compile key — ``(program_key,
    codec/downlink signature, argument treedef + leaf shapes/dtypes)`` — so
    two experiments share an executable exactly when they would lower the
    same traced program for the same input signature (DESIGN.md §12). The
    fleet driver hands one registry to every sweep point; points whose
    model/bucket/transport signatures coincide compile once and dispatch N
    times.

    ``get_or_build`` is thread-safe and single-flight: when packed sweep
    points race on one key, exactly one thread compiles while the rest wait
    on the in-flight event — "compile once, dispatch N" holds under
    concurrent packing, and the reuse counters stay exact.
    """

    def __init__(self):
        self._entries: Dict[Tuple, Any] = {}
        self._inflight: Dict[Tuple, threading.Event] = {}
        self._lock = threading.Lock()
        self.hits = 0          # lookups served from an existing entry
        self.misses = 0        # lookups that compiled a new entry

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple) -> bool:
        with self._lock:
            return key in self._entries

    @property
    def compile_count(self) -> int:
        """Distinct executables compiled into this registry (exact)."""
        return len(self._entries)

    def executables(self) -> Tuple[Any, ...]:
        with self._lock:
            return tuple(self._entries.values())

    def get_or_build(self, key: Tuple, build: Callable[[], Any]
                     ) -> Tuple[Any, bool]:
        """Return ``(executable, built)``: the cached entry for ``key``, or
        the result of ``build()`` (stored under ``key``). ``built`` is True
        only for the caller that actually compiled — a concurrent caller
        that waited on the in-flight compile gets ``built=False``, so
        per-engine compile counters never double-count one compilation."""
        while True:
            with self._lock:
                exe = self._entries.get(key)
                if exe is not None:
                    self.hits += 1
                    return exe, False
                ev = self._inflight.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[key] = ev
                    break
            ev.wait()           # someone else is compiling this key
        try:
            exe = build()
        except BaseException:
            with self._lock:
                del self._inflight[key]
            ev.set()
            raise
        with self._lock:
            self._entries[key] = exe
            del self._inflight[key]
            self.misses += 1
        ev.set()
        return exe, True


class RoundEngine:
    """Bucket executor with an explicit per-signature executable registry.

    The backend decides execution geometry and placement; the engine owns
    compilation: ``run_bucket`` looks the placed arguments' signature up in
    the registry and AOT-compiles (``jit(...).lower(...).compile()``) on
    miss — one executable per distinct signature, counted exactly by
    ``compile_count`` (no reliance on private jit cache probes).
    """

    def __init__(self, loss_fn: LossFn, *, aggregator: str = "mean",
                 trim_fraction: float = 0.1, server: str = "avg",
                 server_lr: float = 1.0,
                 backend: Optional[ExecutionBackend] = None,
                 transport=None, topk_frac: float = 0.1, downlink=None,
                 downlink_ref: str = "f32",
                 cohort_chunk: Optional[int] = None,
                 registry: Optional[ExecutableRegistry] = None,
                 program_key: Optional[Tuple] = None):
        """``transport``: None/"none" keeps the historical param-space
        aggregation path bit-for-bit; "int8"/"int8x2"/"topk" (or a
        ``Transport`` instance) routes aggregation through the compressed
        delta pipeline (DESIGN.md §8). Compressed codecs require a linear
        aggregator; their error-feedback state is engine-owned
        (``transport_state``) and threads through every bucket scan.

        ``downlink``: None/"none" keeps the historical uncompressed server
        broadcast bit-for-bit; a codec name (or ``DownlinkCodec``) makes
        every round reconstruct the client model as ``params_ref +
        decode(payload)`` before local SGD (DESIGN.md §8.6) — decoded
        lazily inside the client step (DESIGN.md §10). The broadcast
        reference + downlink residual are engine-owned
        (``downlink_state``) and thread the bucket scan carry alongside
        the uplink state. Orthogonal to the aggregator choice.

        ``downlink_ref``: storage for the engine-owned broadcast reference
        and residual — "f32" (default, bit-exact PR-5 behaviour) or "q8"
        (int8+scale leaves, ~2x less server-held state, DESIGN.md §10.3).
        Requires a configured downlink codec.

        ``registry``: a shared ``ExecutableRegistry`` for cross-experiment
        executable reuse (DESIGN.md §12). When given, ``program_key`` is
        required — a hashable fingerprint of everything that shapes the
        traced program but is NOT in the input signature (model/task,
        aggregator/server, transport+downlink config, backend placement).
        Entries are keyed ``(program_key, codec_sig) + signature``, so two
        engines whose program keys and signatures coincide share one AOT
        executable; distinct codecs/backends never collide because their
        keys differ. Omitted, the engine owns a private registry and
        behaves exactly as before."""
        self.backend = backend if backend is not None else LocalBackend()
        self.transport = get_transport(transport, topk_frac=topk_frac)
        if self.transport is not None and \
                getattr(self.transport, "name", "") != "none" and \
                aggregator not in LINEAR_AGGREGATORS:
            raise ValueError(
                f"transport {self.transport.name!r} requires a linear "
                f"aggregator {LINEAR_AGGREGATORS}, got {aggregator!r}")
        self.downlink = self.backend.bind_downlink(
            get_downlink(downlink, topk_frac=topk_frac,
                         ref_store=downlink_ref))
        if self.downlink is None and downlink_ref != "f32":
            raise ValueError(
                f"downlink_ref={downlink_ref!r} requires a downlink codec")
        self.server = get_server_optimizer(server)
        self.round_core = self.backend.make_round_core(
            loss_fn, aggregator=aggregator, trim_fraction=trim_fraction,
            server=self.server, server_lr=server_lr,
            transport=self.transport, downlink=self.downlink)
        # streaming cohorts (DESIGN.md §11): the slab/finalize jits exist
        # only when chunking is on — cohort_chunk=None leaves the engine's
        # compiled program (and its executable registry) bit-for-bit
        # identical to the unchunked build
        self.cohort_chunk = cohort_chunk
        if cohort_chunk:
            if self.downlink is not None:
                raise ValueError(
                    "cohort_chunk cannot combine with a downlink codec: "
                    "the broadcast reference advances round-atomically and "
                    "does not stream over slabs")
            if aggregator not in LINEAR_AGGREGATORS:
                raise ValueError(
                    f"cohort_chunk requires a linear aggregator "
                    f"{LINEAR_AGGREGATORS}: streaming slabs fold into a "
                    f"running weighted sum, got {aggregator!r}")
            slab_core, fin_core = self.backend.make_slab_cores(
                loss_fn, aggregator=aggregator, server=self.server,
                server_lr=server_lr, transport=self.transport)
            chunk_per_client = (self.transport is not None
                                and self.transport.ef_slots is not None)

            def slab(params, batches, weights, eta, acc, ef):
                acc, f, l, ef = slab_core(params, batches, weights, eta,
                                          acc, ef)
                be = self.backend
                acc = (be.constrain_update(acc[0]),
                       be.constrain_update(acc[1]))
                ef = be.constrain_transport_update(
                    ef, per_client=chunk_per_client)
                return acc, f, l, ef

            def slabfin(params, acc, server_state):
                p, s, res = fin_core(params, acc, server_state)
                be = self.backend
                return be.constrain_update(p), s, be.constrain_update(res)

            self._jit_slab = jax.jit(slab)
            self._jit_slabfin = jax.jit(slabfin)
        # codec signature participates in the executable-registry key; the
        # downlink signature nests around it only when a downlink codec is
        # configured, so downlink="none" keys are untouched
        self._codec_sig = (() if self.transport is None
                           else self.transport.signature())
        if self.downlink is not None:
            self._codec_sig = (self._codec_sig, self.downlink.signature())
        if self.transport is None and self.downlink is None:
            raw = make_bucket_fn(self.round_core)

            def bucket(params, batches, weights, etas, active, server_state):
                p, f, l, s = raw(params, batches, weights, etas, active,
                                 server_state)
                return self.backend.constrain_update(p), f, l, s
        elif self.downlink is None:
            raw = make_transport_bucket_fn(self.round_core)
            per_client = self.transport.ef_slots is not None

            def bucket(params, batches, weights, etas, active, server_state,
                       t_state):
                p, f, l, s, t = raw(params, batches, weights, etas, active,
                                    server_state, t_state)
                be = self.backend
                return (be.constrain_update(p), f, l, s,
                        be.constrain_transport_update(t,
                                                      per_client=per_client))
        else:
            # downlink-fused core (built by the backend, DESIGN.md §10):
            # the bucket scan threads the downlink state and stacks the
            # per-round adaptive levels
            raw = make_downlink_bucket_fn(self.round_core)
            per_client = (self.transport is not None
                          and self.transport.ef_slots is not None)

            def bucket(params, batches, weights, etas, active, server_state,
                       extra):
                p, f, l, s, extra, levels = raw(params, batches, weights,
                                                etas, active, server_state,
                                                extra)
                be = self.backend
                d_state = extra if self.transport is None else extra[1]
                d_state = {
                    "ref": be.constrain_update(d_state["ref"]),
                    "res": be.constrain_update(d_state["res"]),
                }
                if self.transport is not None:
                    t = be.constrain_transport_update(extra[0],
                                                      per_client=per_client)
                    extra = (t, d_state)
                else:
                    extra = d_state
                return be.constrain_update(p), f, l, s, extra, levels
        self._jitted = jax.jit(bucket)
        if registry is not None and program_key is None:
            raise ValueError(
                "a shared ExecutableRegistry requires a program_key: the "
                "registry is keyed across experiments, so the engine must "
                "know which traced program its entries belong to")
        self._registry = registry if registry is not None \
            else ExecutableRegistry()
        self._program_key = program_key if program_key is not None else ()
        # engine-local view of the registry entries this engine touched:
        # mem.engine_peak_mb sizes live executables through it, and it keeps
        # the private-registry case bit-for-bit (compile_count == len)
        self._executables: Dict[Tuple, Any] = {}
        self._own_keys: set = set()     # compiled by THIS engine
        self._shared_keys: set = set()  # adopted from the shared registry
        self.dispatch_count = 0
        # wire-state ownership lives in a GlobalModelStore (DESIGN.md §14);
        # the engine starts with a private one and the trainer re-binds its
        # own via bind_store(). transport_state/downlink_state stay
        # readable/writable attributes (store-backed properties below).
        self._store = GlobalModelStore(downlink=self.downlink)
        # (B,) int32 adaptive levels of the most recent bucket (-1 entries:
        # padding rounds / fixed-rate codecs); None until a downlink bucket
        # has run. The trainer reads this right after each dispatch to
        # charge the wire per level (DESIGN.md §10.4).
        self.last_downlink_levels = None

    def bind_store(self, store: GlobalModelStore) -> GlobalModelStore:
        """Adopt a trainer-owned GlobalModelStore as the wire-state owner.
        Any state the private store already holds migrates over; the codec
        binding moves with it so ``store.snapshot()`` brackets through this
        engine's ``store_tree``/``load_tree`` path."""
        store.downlink = self.downlink
        store.transport_state = self._store.transport_state
        store.downlink_state = self._store.downlink_state
        self._store = store
        return store

    @property
    def transport_state(self) -> Any:
        return self._store.transport_state

    @transport_state.setter
    def transport_state(self, value: Any) -> None:
        self._store.transport_state = value

    @property
    def downlink_state(self) -> Any:
        return self._store.downlink_state

    @downlink_state.setter
    def downlink_state(self, value: Any) -> None:
        self._store.downlink_state = value

    def _lookup(self, key: Tuple, jitted, args):
        """Fetch (or AOT-compile) the executable for ``key``.

        The full registry key prepends ``program_key`` so shared registries
        never alias across experiments; counters are exact either way: a
        key this engine compiled lands in ``_own_keys`` (-> compile_count),
        a registry hit built by another engine lands in ``_shared_keys``
        (-> shared_count) and is never double-counted as a local compile.

        Private registries (no program_key) keep the bare legacy key shape
        — ``key[0]`` stays the "slab"/"slabfin" tag some introspection
        relies on; aliasing is impossible in a single-engine registry.
        """
        full_key = (self._program_key,) + key if self._program_key else key
        exe = self._executables.get(full_key)
        if exe is None:
            exe, built = self._registry.get_or_build(
                full_key, lambda: jitted.lower(*args).compile())
            self._executables[full_key] = exe
            (self._own_keys if built else self._shared_keys).add(full_key)
        return exe

    def init_server_state(self, params: PyTree) -> Any:
        return self.server.init(params)

    def init_transport_state(self, params: PyTree) -> Any:
        """Create (and own) the codec's error-feedback state. Engine-owned
        so ``run_bucket``'s signature and 4-tuple result stay unchanged;
        the trainer checkpoints it via ``transport_state``."""
        self.transport_state = (() if self.transport is None
                                else self.transport.init_state(params))
        return self.transport_state

    def init_downlink_state(self, params: PyTree) -> Any:
        """Create (and own) the downlink broadcast state: the reference
        params every client holds plus the downlink EF residual
        (DESIGN.md §8.6). The trainer checkpoints it via
        ``downlink_state``."""
        self.downlink_state = (() if self.downlink is None
                               else self.downlink.init_state(params))
        return self.downlink_state

    def run_bucket(self, params, batches, weights, etas, active, server_state
                   ) -> Tuple[PyTree, jnp.ndarray, jnp.ndarray, Any]:
        """batches leaves (B, N, K, b, ...); weights (B, N); etas/active (B,).

        Inputs may be host (numpy) or already-placed device arrays — the
        backend's placement hooks are idempotent, so prefetched buckets that
        were ``device_put`` on the build thread pass through untouched.
        """
        be = self.backend
        params = be.place_params(params)
        batches = be.place_batches(batches)
        weights = be.place_weights(weights)
        etas, active = be.place_scalars(etas, active)
        server_state = jax.tree.map(jnp.asarray, server_state)
        has_t, has_d = self.transport is not None, self.downlink is not None
        if not has_t and not has_d:
            args = (params, batches, weights, etas, active, server_state)
        else:
            if has_t:
                if self.transport_state is None:
                    self.init_transport_state(params)
                t_state = be.place_transport_state(
                    self.transport_state,
                    per_client=self.transport.ef_slots is not None)
            if has_d:
                if self.downlink_state is None:
                    self.init_downlink_state(params)
                d_state = be.place_downlink_state(self.downlink_state)
            extra = ((t_state, d_state) if has_t and has_d
                     else (t_state if has_t else d_state))
            args = (params, batches, weights, etas, active, server_state,
                    extra)
        key = (self._codec_sig,) + _signature(args)
        exe = self._lookup(key, self._jitted, args)
        self.dispatch_count += 1
        out = exe(*args)
        if not has_t and not has_d:
            return out
        if has_d:
            params, firsts, lasts, server_state, extra, levels = out
            self.last_downlink_levels = levels
        else:
            params, firsts, lasts, server_state, extra = out
        if has_t and has_d:
            self.transport_state, self.downlink_state = extra
        elif has_t:
            self.transport_state = extra
        else:
            self.downlink_state = extra
        return params, firsts, lasts, server_state

    def run_round_chunked(self, params, slabs, eta, server_state
                          ) -> Tuple[PyTree, jnp.ndarray, jnp.ndarray, Any]:
        """Execute ONE round as streamed C-client slabs (DESIGN.md §11).

        ``slabs``: iterable of ``pipeline.SlabBatch`` covering the round's
        cohort in order (host or already-placed — ``place_slab`` is
        idempotent). Device memory in the client dim is O(C): the only
        cross-slab device state is the params-shaped f32 accumulator pair
        plus the current slab's EF slice. Returns the ``run_bucket``
        4-tuple with a B == 1 leading dim on the stacked losses.

        Engine-owned EF state commits round-atomically — per-client slab
        residuals accumulate host-side and replace ``transport_state`` only
        after the finalize step, so a checkpoint taken between rounds can
        never observe mid-round slab state.
        """
        if not self.cohort_chunk:
            raise ValueError("engine was built without cohort_chunk")
        be = self.backend
        params = be.place_params(params)
        server_state = jax.tree.map(jnp.asarray, server_state)
        has_t = self.transport is not None
        per_client = has_t and self.transport.ef_slots is not None
        agg_ef = (has_t and self.transport.error_feedback
                  and not per_client)
        if has_t and self.transport_state is None:
            self.init_transport_state(params)
        zeros = be.place_params(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        acc = (zeros, zeros if agg_ef else ())
        eta = jnp.asarray(eta, jnp.float32)
        firsts, lasts, ef_parts = [], [], []
        for sb in slabs:
            sb = be.place_slab(sb)
            ef = ()
            if per_client:
                ef = be.place_transport_state(
                    jax.tree.map(lambda s: s[sb.start:sb.stop],
                                 self.transport_state), per_client=True)
            elif agg_ef:
                ef = be.place_transport_state(self.transport_state)
            args = (params, sb.batches, sb.weights, eta, acc, ef)
            key = ("slab", self._codec_sig) + _signature(args)
            exe = self._lookup(key, self._jit_slab, args)
            acc, f, l, ef = exe(*args)
            firsts.append(f)
            lasts.append(l)
            if per_client:
                ef_parts.append(ef)
        if not firsts:
            raise ValueError("run_round_chunked got an empty slab stream")
        fargs = (params, acc, server_state)
        key = ("slabfin", self._codec_sig) + _signature(fargs)
        exe = self._lookup(key, self._jit_slabfin, fargs)
        new_params, server_state, new_res = exe(*fargs)
        if per_client:
            self.transport_state = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *ef_parts)
        elif agg_ef:
            self.transport_state = new_res
        self.dispatch_count += 1
        return (new_params, jnp.concatenate(firsts)[None],
                jnp.concatenate(lasts)[None], server_state)

    @property
    def compile_count(self) -> int:
        """Distinct bucket executables built BY THIS ENGINE (exact). With a
        private registry this equals the historical registry size; with a
        shared registry, executables adopted from other experiments are
        excluded — they count under ``shared_count`` instead."""
        return len(self._own_keys)

    @property
    def shared_count(self) -> int:
        """Distinct executables this engine reused from the shared registry
        without compiling (0 with a private registry)."""
        return len(self._shared_keys)

    @property
    def registry(self) -> ExecutableRegistry:
        return self._registry


def make_round_fn(loss_fn: LossFn, *, server: str = "avg",
                  server_lr: float = 1.0, aggregator: str = "mean",
                  use_kernel_avg: Optional[bool] = None):
    """Seed-compatible single-round builder (one jitted FedAvg round).

    round_fn(params, batches{(N,K,b,...)}, weights (N,), eta, server_state)
        -> (new_params, first_losses (N,), mean_last_loss, server_state)

    Returns ``(round_fn, srv_init)`` where ``srv_init`` is None for the
    stateless ``avg`` server (its state is ``()``), matching the historical
    ``make_round_fn`` contract that `tests` and benchmarks rely on.

    ``aggregator`` resolves through the plugin registry;
    ``use_kernel_avg`` is DEPRECATED — pass ``aggregator="kernel"``.
    """
    if use_kernel_avg is not None:
        import warnings
        warnings.warn(
            "make_round_fn(use_kernel_avg=...) is deprecated and will be "
            "removed next release; pass aggregator='kernel' instead.",
            DeprecationWarning, stacklevel=2)
        if use_kernel_avg:
            aggregator = "kernel"
    srv = get_server_optimizer(server)
    core = make_round_core(loss_fn, get_aggregator(aggregator), srv,
                           server_lr)

    def round_fn(params, batches, weights, eta, server_state):
        new_params, first_losses, last_losses, server_state = core(
            params, batches, weights, eta, server_state)
        return new_params, first_losses, jnp.mean(last_losses), server_state

    srv_init = None if server == "avg" else srv.init
    return jax.jit(round_fn), srv_init
