"""AsyncBufferedEngine — FedBuff-style buffered aggregation on a simulated
event clock (DESIGN.md §13).

The round-synchronous trainer waits for its whole cohort every round; at
population scale the server never sees a clean cohort boundary. This engine
keeps ``fed.clients_per_round`` clients permanently in flight: each client
computes its K-step local update from whatever global version it last
received, and its delta arrives after a per-client duration drawn from the
RuntimeModel's heterogeneity model. Arrivals fold into a streaming f32
buffer scaled by a pluggable staleness weight; when ``buffer_size`` updates
have been folded the server applies the buffer through the ordinary
ServerOptimizer step and bumps the global version.

Determinism — the *simulated event clock*:

  * durations come from ``RuntimeModel.draw_client_times`` in counter mode
    (a pure function of (seed, dispatch index, client id)), so the event
    trace is exact, replayable and needs no extra rng state checkpointed;
  * the event loop is a heap of ``(finish_time, seq, slot)``; ties (all of
    them, at heterogeneity 0) resolve by dispatch order;
  * every event group that frees slots redispatches them as ONE vmapped
    group from the current params — at ``heterogeneity == 0`` and
    ``buffer_size == cohort`` the groups are whole cohorts, the sampler and
    per-client batch draws consume EXACTLY the synchronous trainer's rng
    stream, and the loss trajectory reproduces the round-synchronous run
    (the sync-parity oracle, tests/test_async.py).

Buffer-fold contract: an arrival from start version ``v0`` at current
version ``v`` has staleness ``s = v - v0`` and folds as

    buffer     += staleness_weight(s) * w_c * delta_c
    buf_weight += staleness_weight(s) * w_c

with ``w_c`` the client's sampler weight inside its dispatch group. The
apply step normalises: ``aggregate = params + buffer / buf_weight`` —
scale-invariant in the weight function, so ``constant`` reproduces the
synchronous weighted mean exactly when the buffer holds one whole cohort.
Arrivals staler than ``fed.max_staleness`` are dropped (counted, slot
refilled, wire still charged — the bytes were shipped).

Uplink deltas ride the existing Transport layer: each arrival is encoded /
decoded through ``Transport.aggregate_slab`` with a per-slot error-feedback
residual (the in-flight slot IS the per-version residual slot — concurrency
is fixed, so slot j's residual always compensates the next update computed
from that lane). Downlink codecs are refused: async clients hold skewed
versions, which the single broadcast-reference state machine cannot encode.

Everything checkpoints: buffer + fold weight, per-slot in-flight deltas /
client ids / start versions (the version vector) / losses, the event heap,
per-slot EF residuals, both rng streams and the byte counters — a mid-buffer
``save_state`` -> ``restore_state`` resumes bitwise (tests/test_async.py).
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registries import (register_aggregation,
                                  register_staleness_weight)
from repro.configs.base import FedConfig
from repro.core.engine.backends.base import LINEAR_AGGREGATORS
from repro.core.engine.client import make_client_update
from repro.core.engine.model_store import GlobalModelStore
from repro.core.engine.round import ExecutableRegistry, LossFn, _signature
from repro.core.engine.sampling import make_sampler
from repro.core.engine.server import get_server_optimizer
from repro.core.engine.transport import get_transport
from repro.core.engine.trainer import History
from repro.core.runtime_model import RuntimeModel
from repro.core.schedules import DecayController
from repro.data import pipeline
from repro.data.synthetic import FederatedData

PyTree = Any

STALENESS_WEIGHTS = ("constant", "inv", "poly")   # builtins

# poly staleness weight exponent: (1 + s)^-POLY_ALPHA (FedBuff's
# polynomial family; 0.5 is the paper's default)
POLY_ALPHA = 0.5

register_staleness_weight("constant", lambda **kw: lambda s: 1.0)
register_staleness_weight("inv", lambda **kw: lambda s: 1.0 / (1.0 + s))
register_staleness_weight(
    "poly", lambda **kw: lambda s: (1.0 + s) ** -POLY_ALPHA)


def get_staleness_weight(name) -> Callable[[int], float]:
    from repro.api.registries import STALENESS_WEIGHT_REGISTRY
    if callable(name):
        return name
    return STALENESS_WEIGHT_REGISTRY.get(name)()


class AsyncBufferedEngine:
    """Drop-in trainer for ``fed.aggregation="async"`` — the FedAvgTrainer
    surface (``run``/``save_state``/``restore_state``/``history``/compile
    counters) on the buffered-asynchronous execution model above."""

    def __init__(self, loss_fn: LossFn, init_params: PyTree,
                 data: FederatedData, fed: FedConfig,
                 runtime: RuntimeModel,
                 eval_fn: Optional[Callable[[PyTree],
                                            Dict[str, float]]] = None,
                 backend=None, sampler=None, registry=None,
                 program_key=None):
        from repro.core.engine.backends.local import LocalBackend
        self.loss_fn = loss_fn
        self.data = data
        self.fed = fed
        self.eval_fn = eval_fn
        self.ctrl = DecayController(fed)
        self.backend = backend if backend is not None else LocalBackend()

        # --- engine-time refusals (mirror spec.validate, DESIGN.md §13.5) --
        if fed.aggregator not in LINEAR_AGGREGATORS:
            raise ValueError(
                f"async buffered aggregation folds arrivals into a running "
                f"weighted sum and requires a linear aggregator "
                f"{LINEAR_AGGREGATORS}, got {fed.aggregator!r} — use "
                f"aggregation='sync' for robust aggregators")
        if getattr(fed, "cohort_chunk", None):
            raise ValueError(
                "cohort_chunk does not compose with async aggregation: the "
                "async engine already streams arrivals one at a time — drop "
                "cohort_chunk")
        if getattr(self.backend, "strategy", "parallel") == "sequential":
            raise ValueError(
                "the mesh sequential strategy scans a whole synchronous "
                "cohort; async dispatch groups are ragged — use the "
                "parallel strategy")
        if getattr(fed, "downlink", "none") != "none":
            raise ValueError(
                "async clients start from skewed global versions; the "
                "broadcast-reference downlink state machine cannot encode "
                "one delta for all of them — set downlink='none'")
        self.sampler = sampler if sampler is not None else make_sampler(fed)
        if self.sampler.stateful_cohort:
            raise ValueError(
                f"sampler {self.sampler.name!r} pins one client per slot, "
                f"but async redispatches ragged groups of freed slots — use "
                f"'uniform' or 'weighted'")

        self.n = min(fed.clients_per_round, data.num_clients)
        buf = getattr(fed, "buffer_size", None)
        self.buffer_size = self.n if buf is None else int(buf)
        if not 1 <= self.buffer_size <= self.n:
            raise ValueError(
                f"buffer_size must be in [1, clients_per_round={self.n}], "
                f"got {self.buffer_size}: a larger buffer can never fill "
                f"past the in-flight cohort")
        self.staleness_weight = get_staleness_weight(
            getattr(fed, "staleness_weight", "constant"))
        self.max_staleness = getattr(fed, "max_staleness", None)

        self.server = get_server_optimizer(fed.server_optimizer)
        self.server_lr = fed.server_lr
        transport = get_transport(getattr(fed, "transport", "none"),
                                  topk_frac=getattr(fed, "topk_frac", 0.1))
        if transport is not None and transport.error_feedback:
            # one residual slot per in-flight lane: concurrency is fixed, so
            # lane j's residual always compensates the next update computed
            # from that lane — the "per-version EF slot" of DESIGN.md §13.4
            transport = transport.with_ef_slots(self.n)
        self.transport = transport
        self._codec_sig = (() if transport is None else transport.signature())

        # the GlobalModelStore owns params / server state / transport EF /
        # the version counter / cost counters; the attribute names below
        # are store-backed properties (DESIGN.md §14). No downlink codec in
        # async, so snapshot() serves params itself.
        self.store = GlobalModelStore()
        self.params = self.backend.place_params(init_params)
        self.server_state = self.server.init(init_params)
        self.transport_state = (() if transport is None
                                else transport.init_state(init_params))

        self.runtime = runtime
        if transport is not None:
            # charge the wire what the codec ships, on an engine-owned copy
            # (shared RuntimeModels keep their own stream), as the sync
            # trainer does
            import copy as _copy
            rt = _copy.copy(runtime)
            rt._rng = np.random.default_rng()
            rt._rng.bit_generator.state = runtime._rng.bit_generator.state
            rt.uplink_compression = transport.compression_ratio(init_params)
            self.runtime = rt

        if registry is not None and program_key is None:
            raise ValueError(
                "a shared ExecutableRegistry requires a program_key (see "
                "RoundEngine)")
        self._registry = registry if registry is not None \
            else ExecutableRegistry()
        self._program_key = program_key if program_key is not None else ()
        self._executables: Dict[Tuple, Any] = {}
        self._own_keys: set = set()
        self._shared_keys: set = set()
        self.dispatch_count = 0

        self._dispatch_jit = jax.jit(self._dispatch_fn)
        self._fold_jit = jax.jit(self._fold_fn)
        self._apply_jit = jax.jit(self._apply_fn)

        self.history = History()
        self._np_rng = np.random.default_rng(fed.seed)

        # --- simulation state (all of it checkpoints) -------------------
        self._started = False
        self._sim_time = 0.0
        self._version = 0            # applied-buffer count == "round" index
        self._seq = 0                # event tie-break, monotone
        self._dispatch_idx = 0       # counter-mode duration stream index
        self._heap: List[Tuple[float, int, int]] = []
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             init_params)
        # per-slot in-flight state: stacked deltas (n, ...) + host metadata
        self._inflight = jax.tree.map(
            lambda p: jnp.zeros((self.n,) + tuple(p.shape), jnp.float32),
            init_params)
        self._slot_client = np.full(self.n, -1, np.int64)
        self._slot_version = np.full(self.n, -1, np.int64)   # version vector
        self._slot_weight = np.zeros(self.n, np.float64)
        self._slot_first = np.zeros(self.n, np.float64)
        self._slot_last = np.zeros(self.n, np.float64)
        self._slot_k = np.zeros(self.n, np.int64)
        self._buffer = zeros
        self._buf_weight = 0.0
        self._buf_count = 0
        self._buf_first_losses: List[float] = []
        self._buf_staleness: List[int] = []
        self.applied_updates = 0
        self.dropped_updates = 0
        self.staleness_hist: Dict[int, int] = {}
        self._completed_rounds = 0
        # serve-while-training: api.build attaches a ServingLoop + cadence;
        # ticks ride buffer applications (DESIGN.md §14)
        self.serving = None
        self.serve_every = 0

    # ------------------------------------------------------------------
    # state delegation: the GlobalModelStore owns it, the historical
    # attribute names keep reading/writing it
    # ------------------------------------------------------------------
    params = property(lambda self: self.store.params,
                      lambda self, v: setattr(self.store, "params", v))
    server_state = property(
        lambda self: self.store.server_state,
        lambda self, v: setattr(self.store, "server_state", v))
    transport_state = property(
        lambda self: self.store.transport_state,
        lambda self, v: setattr(self.store, "transport_state", v))
    _version = property(lambda self: self.store.version,
                        lambda self, v: setattr(self.store, "version", v))
    _steps = property(lambda self: self.store.steps,
                      lambda self, v: setattr(self.store, "steps", v))
    _up_mbit = property(lambda self: self.store.up_mbit,
                        lambda self, v: setattr(self.store, "up_mbit", v))
    _down_mbit = property(lambda self: self.store.down_mbit,
                          lambda self, v: setattr(self.store, "down_mbit", v))
    _min_loss = property(lambda self: self.store.min_loss,
                         lambda self, v: setattr(self.store, "min_loss", v))
    _max_acc = property(lambda self: self.store.max_acc,
                        lambda self, v: setattr(self.store, "max_acc", v))

    # ------------------------------------------------------------------
    # jitted cores (AOT-cached per input signature, like RoundEngine)
    # ------------------------------------------------------------------
    def _dispatch_fn(self, params, batches, eta):
        """(params, batches (m, K, b, ...), eta) -> (deltas f32 (m, ...),
        first (m,), last (m,)) — the eager client compute at dispatch."""
        update = make_client_update(self.loss_fn)
        res = jax.vmap(lambda b: update(params, b, eta))(batches)
        p32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        deltas = jax.tree.map(
            lambda cp, p: cp.astype(jnp.float32) - p[None], res.params, p32)
        return deltas, res.first_loss, res.last_loss

    def _fold_fn(self, buffer, delta, w, ef):
        """Fold one arrival: encode/decode through the transport (per-slot
        EF residual compensation included) and add ``w * decoded`` into the
        running f32 buffer. ``w`` already carries sampler x staleness
        weight. Returns (buffer, new_ef)."""
        if self.transport is None:
            new_buf = jax.tree.map(lambda b, d: b + w * d, buffer, delta)
            return new_buf, ef
        zeros = jax.tree.map(lambda d: jnp.zeros_like(d), delta)
        hat, _true, new_ef = self.transport.aggregate_slab(
            zeros, jax.tree.map(lambda d: d[None], delta),
            jnp.ones((1,), jnp.float32), ef)
        new_buf = jax.tree.map(lambda b, h: b + w * h, buffer, hat)
        return new_buf, new_ef

    def _apply_fn(self, params, buffer, buf_weight, server_state):
        """aggregate = params + buffer / buf_weight, through the ordinary
        ServerOptimizer step (fedavgm/fedyogi compose unchanged)."""
        inv = jnp.where(buf_weight > 0, 1.0 / buf_weight, 0.0)
        aggregate = jax.tree.map(
            lambda p, b: p.astype(jnp.float32) + inv * b, params, buffer)
        new_params, new_state = self.server.step(params, aggregate,
                                                 server_state, self.server_lr)
        zeros = jax.tree.map(lambda b: jnp.zeros_like(b), buffer)
        return new_params, new_state, zeros

    def _run_exe(self, tag: str, jitted, args):
        key = ((self._program_key,) if self._program_key else ()) \
            + (tag, self._codec_sig) + _signature(args)
        exe = self._executables.get(key)
        if exe is None:
            exe, built = self._registry.get_or_build(
                key, lambda: jitted.lower(*args).compile())
            self._executables[key] = exe
            (self._own_keys if built else self._shared_keys).add(key)
        self.dispatch_count += 1
        return exe(*args)

    @property
    def compile_count(self) -> int:
        return len(self._own_keys)

    @property
    def shared_count(self) -> int:
        return len(self._shared_keys)

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def _dispatch_group(self, slots: List[int]) -> None:
        """Draw a cohort group for the freed ``slots``, compute their local
        updates from the CURRENT params (the version they just received),
        and schedule their arrivals. One sampler draw + per-client batch
        draws in slot order — at zero jitter with whole-cohort groups this
        is exactly the synchronous ``bucket_batches`` stream."""
        m = len(slots)
        r = self._version + 1                     # the round being fed
        k = self.ctrl.k_for_round(r)
        eta = self.ctrl.eta_for_round(r)
        ids, w = self.sampler.round(self._np_rng, self.data, m, r)
        b = self.fed.batch_size
        feat = self.data.client_x[ids[0]].shape[1:]
        yfeat = self.data.client_y[ids[0]].shape[1:]
        xs = np.empty((m, k, b) + feat, self.data.client_x[ids[0]].dtype)
        ys = np.empty((m, k, b) + yfeat, self.data.client_y[ids[0]].dtype)
        for j, c in enumerate(ids):
            n_c = len(self.data.client_y[c])
            idx = self._np_rng.integers(0, n_c, size=k * b)
            np.take(self.data.client_x[c], idx, axis=0,
                    out=xs[j].reshape((k * b,) + feat))
            np.take(self.data.client_y[c], idx, axis=0,
                    out=ys[j].reshape((k * b,) + yfeat))
        batches = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        args = (self.params, batches, jnp.asarray(eta, jnp.float32))
        deltas, first, last = self._run_exe("async-dispatch",
                                            self._dispatch_jit, args)
        first = np.asarray(first)
        last = np.asarray(last)
        # scatter the group into the in-flight slots (host-side: the slot
        # axis is small and the copy overlaps nothing)
        sl = np.asarray(slots)
        self._inflight = jax.tree.map(
            lambda tree, d: tree.at[sl].set(d), self._inflight, deltas)
        times = self.runtime.draw_client_times(self._dispatch_idx, ids, k)
        self._dispatch_idx += 1
        for j, slot in enumerate(slots):
            self._slot_client[slot] = ids[j]
            self._slot_version[slot] = self._version
            self._slot_weight[slot] = float(w[j])
            self._slot_first[slot] = float(first[j])
            self._slot_last[slot] = float(last[j])
            self._slot_k[slot] = k
            heapq.heappush(self._heap,
                           (float(self._sim_time + times[j]), self._seq,
                            slot))
            self._seq += 1
        self._steps += k * m
        self._down_mbit += self.runtime.downlink_mbit_per_client * m

    def _fold_arrival(self, slot: int) -> None:
        """One arrival: staleness-weighted fold into the buffer (or a
        max-staleness drop). The wire is charged either way — the bytes
        were shipped."""
        self._up_mbit += self.runtime.uplink_mbit_per_client
        s = int(self._version - self._slot_version[slot])
        self.staleness_hist[s] = self.staleness_hist.get(s, 0) + 1
        if self.max_staleness is not None and s > self.max_staleness:
            self.dropped_updates += 1
            return
        w = float(self._slot_weight[slot]) * float(self.staleness_weight(s))
        delta = jax.tree.map(lambda t: t[slot], self._inflight)
        ef = ()
        if self.transport is not None and self.transport.error_feedback:
            ef = jax.tree.map(lambda t: t[slot:slot + 1],
                              self.transport_state)
        args = (self._buffer, delta, jnp.asarray(w, jnp.float32), ef)
        self._buffer, new_ef = self._run_exe("async-fold", self._fold_jit,
                                             args)
        if self.transport is not None and self.transport.error_feedback:
            self.transport_state = jax.tree.map(
                lambda t, n: t.at[slot:slot + 1].set(n),
                self.transport_state, new_ef)
        self._buf_weight += w
        self._buf_count += 1
        self._buf_first_losses.append(float(self._slot_first[slot]))
        self._buf_staleness.append(s)

    def _apply_buffer(self, verbose: bool, eval_every: Optional[int]) -> None:
        args = (self.params, self._buffer,
                jnp.asarray(self._buf_weight, jnp.float32), self.server_state)
        self.params, self.server_state, self._buffer = self._run_exe(
            "async-apply", self._apply_jit, args)
        self.applied_updates += self._buf_count
        self._version += 1
        round_loss = float(np.mean(self._buf_first_losses))
        self.ctrl.observe_round_losses(round_loss)
        self._min_loss = min(self._min_loss, round_loss)
        h = self.history
        r = self._version
        h.rounds.append(r)
        h.k.append(self.ctrl.k_for_round(r))
        h.eta.append(self.ctrl.eta_for_round(r))
        h.wall_clock_s.append(self._sim_time)     # the event clock IS wall
        h.sgd_steps.append(self._steps)
        h.uplink_mbit.append(self._up_mbit)
        h.downlink_mbit.append(self._down_mbit)
        h.train_loss.append(round_loss)
        h.min_train_loss.append(self._min_loss)
        h.staleness.append(float(np.mean(self._buf_staleness)))
        h.applied_updates.append(self.applied_updates)
        h.dropped_updates.append(self.dropped_updates)
        self._buf_weight = 0.0
        self._buf_count = 0
        self._buf_first_losses = []
        self._buf_staleness = []
        if (self.serving is not None and self.serve_every
                and r % self.serve_every == 0):
            # hot-swap the freshly applied version into the decode service
            self.serving.tick(r, h)
        if eval_every and self.eval_fn is not None and r % eval_every == 0:
            metrics = self.eval_fn(self.params)
            err = metrics.get("error", 1.0 - metrics.get("acc", 0.0))
            self.ctrl.observe_validation(err)
            self._max_acc = max(self._max_acc, metrics.get("acc", 0.0))
            h.val_rounds.append(r)
            h.val_error.append(err)
            h.max_val_acc.append(self._max_acc)
        if verbose:
            print(f"apply {r:5d} K={h.k[-1]:3d} loss={round_loss:.4f} "
                  f"stale={h.staleness[-1]:.2f} W={self._sim_time:.1f}s "
                  f"applied={self.applied_updates} "
                  f"dropped={self.dropped_updates}")

    def run(self, rounds: Optional[int] = None, eval_every: int = 10,
            verbose: bool = False, resume: bool = False) -> History:
        """Advance the event clock until ``rounds`` buffers have been
        applied (``resume=True`` continues a restored run; otherwise a
        second ``run()`` call keeps advancing the same simulation — the
        async engine has no schedule replay)."""
        rounds = rounds if rounds is not None else self.fed.rounds
        if (self.serving is not None
                and self.serving.served_version != self.store.version):
            # restored (or warm-rerun) store is ahead of the loop's
            # construction-time snapshot — re-swap before the clock advances
            self.serving.swap()
        if not self._started:
            self._dispatch_group(list(range(self.n)))
            self._started = True
        while self._version < rounds:
            if not self._heap:
                raise RuntimeError("async event loop drained with no "
                                   "in-flight clients")
            t, _, slot = self._heap[0]
            freed: List[int] = []
            # pop the WHOLE same-timestamp group (deterministic seq order),
            # folding each arrival and applying the buffer whenever it
            # fills mid-group — then redispatch the freed slots as one
            # vmapped group from the now-current params
            while self._heap and self._heap[0][0] == t:
                _, _, slot = heapq.heappop(self._heap)
                self._sim_time = t
                self._fold_arrival(slot)
                freed.append(slot)
                if self._buf_count >= self.buffer_size:
                    self._apply_buffer(verbose, eval_every
                                       if self.eval_fn is not None else None)
            self._dispatch_group(freed)
        self._completed_rounds = self._version
        return self.history

    # ------------------------------------------------------------------
    # checkpointing (bitwise resume, DESIGN.md §13.6)
    # ------------------------------------------------------------------
    def save_state(self, path: str,
                   extra_meta: Optional[Dict[str, Any]] = None) -> None:
        from repro.checkpoint import save_checkpoint
        sd = self.store.state_dict()
        # the store's empty downlink entry contributes no leaves, so the
        # array payload is identical to the pre-store layout
        tree = {**sd["tree"],
                "buffer": self._buffer, "inflight": self._inflight}
        meta = {
            **(extra_meta or {}),
            "completed_rounds": self._completed_rounds,
            "history": self.history.as_dict(),
            "rng": self._np_rng.bit_generator.state,
            "runtime_rng": self.runtime._rng.bit_generator.state,
            "async": {
                "started": self._started,
                "sim_time": self._sim_time,
                "version": self._version,
                "seq": self._seq,
                "dispatch_idx": self._dispatch_idx,
                "heap": [[t, s, sl] for t, s, sl in self._heap],
                "slot_client": self._slot_client.tolist(),
                "slot_version": self._slot_version.tolist(),
                "slot_weight": self._slot_weight.tolist(),
                "slot_first": self._slot_first.tolist(),
                "slot_last": self._slot_last.tolist(),
                "slot_k": self._slot_k.tolist(),
                "buf_weight": self._buf_weight,
                "buf_count": self._buf_count,
                "buf_first_losses": self._buf_first_losses,
                "buf_staleness": self._buf_staleness,
                "applied_updates": self.applied_updates,
                "dropped_updates": self.dropped_updates,
                "staleness_hist": {str(k): v for k, v
                                   in self.staleness_hist.items()},
            },
            **sd["meta"],
            "ctrl": self.ctrl.state_dict(),
        }
        save_checkpoint(path, tree, meta=meta)

    def restore_state(self, path: str) -> None:
        tree, meta = self.store.load_checkpoint_tree(
            path, extra_like={"buffer": self._buffer,
                              "inflight": self._inflight})
        # checkpoint leaves come back as host numpy; the engine needs device
        # arrays (the in-flight scatter uses .at[], and the AOT executables
        # expect placed inputs)
        place = lambda t: jax.tree.map(jnp.asarray, t)
        self.store.restore_tree(tree, place_params=self.backend.place_params,
                                place=place)
        self._buffer = place(tree["buffer"])
        self._inflight = place(tree["inflight"])
        a = meta["async"]
        self._started = bool(a["started"])
        self._sim_time = float(a["sim_time"])
        self._version = int(a["version"])
        self._seq = int(a["seq"])
        self._dispatch_idx = int(a["dispatch_idx"])
        self._heap = [(float(t), int(s), int(sl)) for t, s, sl in a["heap"]]
        heapq.heapify(self._heap)
        self._slot_client = np.asarray(a["slot_client"], np.int64)
        self._slot_version = np.asarray(a["slot_version"], np.int64)
        self._slot_weight = np.asarray(a["slot_weight"], np.float64)
        self._slot_first = np.asarray(a["slot_first"], np.float64)
        self._slot_last = np.asarray(a["slot_last"], np.float64)
        self._slot_k = np.asarray(a["slot_k"], np.int64)
        self._buf_weight = float(a["buf_weight"])
        self._buf_count = int(a["buf_count"])
        self._buf_first_losses = [float(x) for x in a["buf_first_losses"]]
        self._buf_staleness = [int(x) for x in a["buf_staleness"]]
        self.applied_updates = int(a["applied_updates"])
        self.dropped_updates = int(a["dropped_updates"])
        self.staleness_hist = {int(k): int(v)
                               for k, v in a["staleness_hist"].items()}
        self._completed_rounds = int(meta["completed_rounds"])
        self.history = History.from_dict(meta["history"])
        self._np_rng.bit_generator.state = meta["rng"]
        self.runtime._rng.bit_generator.state = meta["runtime_rng"]
        # pre-PR-10 meta has no store_version: the applied-buffer count in
        # the async sub-dict IS the version (restore above already set it,
        # but the counters load keeps both paths symmetric)
        self.store.load_counters_meta(meta,
                                      default_version=int(a["version"]))
        self.ctrl.load_state_dict(meta["ctrl"])


# ---------------------------------------------------------------------------
# AggregationPolicy registry builtins (DESIGN.md §13.1)
# ---------------------------------------------------------------------------

def _sync_policy(loss_fn, init_params, data, fed, runtime, *, eval_fn=None,
                 backend=None, sampler=None, registry=None, program_key=None,
                 **kw):
    from repro.core.engine.trainer import FedAvgTrainer
    return FedAvgTrainer(loss_fn, init_params, data, fed, runtime,
                         eval_fn=eval_fn, backend=backend, sampler=sampler,
                         registry=registry, program_key=program_key)


def _async_policy(loss_fn, init_params, data, fed, runtime, *, eval_fn=None,
                  backend=None, sampler=None, registry=None,
                  program_key=None, **kw):
    return AsyncBufferedEngine(loss_fn, init_params, data, fed, runtime,
                               eval_fn=eval_fn, backend=backend,
                               sampler=sampler, registry=registry,
                               program_key=program_key)


register_aggregation("sync", lambda **kw: _sync_policy)
register_aggregation("async", lambda **kw: _async_policy)
