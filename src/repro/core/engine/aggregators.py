"""Pluggable cross-client aggregation (Algorithm 1, line 11 generalised).

An aggregator maps a client-stacked param pytree (leaves ``(N, ...)``) and
per-client weights ``(N,)`` to the aggregated pytree (leaves ``(...)``).

Variants:
  * ``mean``         — weighted mean via an f32 einsum (the paper's FedAvg)
  * ``kernel``       — same contraction through the Pallas ``fedavg_reduce``
  * ``median``       — coordinate-wise median (robust; ignores weights)
  * ``trimmed_mean`` — coordinate-wise ``beta``-trimmed mean (Yin et al. '18)

Robust variants tolerate Byzantine / corrupted client updates at the cost of
ignoring the sample-count weighting p_c (DESIGN.md §6.2).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.api.registries import AGGREGATOR_REGISTRY, register_aggregator

PyTree = Any
Aggregator = Callable[[PyTree, jnp.ndarray], PyTree]

AGGREGATORS = ("mean", "kernel", "median", "trimmed_mean")   # builtins


def weighted_mean(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """sum_c p_c * x_c, accumulated in f32, cast back to storage dtype."""
    w32 = weights.astype(jnp.float32)
    return jax.tree.map(
        lambda cp: jnp.einsum("c,c...->...", w32,
                              cp.astype(jnp.float32)).astype(cp.dtype),
        client_params)


def kernel_mean(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Weighted mean through the Pallas reduction kernel."""
    from repro.kernels import ops as kops
    return kops.fedavg_reduce_tree(client_params, weights)


def coordinate_median(client_params: PyTree, weights: jnp.ndarray) -> PyTree:
    """Per-coordinate median over the client axis (weights unused)."""
    del weights
    return jax.tree.map(
        lambda cp: jnp.median(cp.astype(jnp.float32), axis=0).astype(cp.dtype),
        client_params)


def trimmed_mean(client_params: PyTree, weights: jnp.ndarray,
                 trim_fraction: float = 0.1) -> PyTree:
    """Drop the ``floor(trim_fraction * N)`` (but, for any positive
    fraction, at least one) largest and smallest values per coordinate,
    then average the survivors uniformly (weights unused). The floor of one
    keeps the robustness guarantee at the small cohort sizes (N of 4-16)
    federated rounds actually use — otherwise a 10% trim of 6 clients trims
    nobody."""
    del weights

    def one(cp):
        n = cp.shape[0]
        t = max(1, int(trim_fraction * n)) if trim_fraction > 0 else 0
        if 2 * t >= n:          # degenerate trim -> median
            return jnp.median(cp.astype(jnp.float32), axis=0).astype(cp.dtype)
        s = jnp.sort(cp.astype(jnp.float32), axis=0)
        kept = s[t:n - t] if t else s
        return jnp.mean(kept, axis=0).astype(cp.dtype)

    return jax.tree.map(one, client_params)


def get_aggregator(name: str, *, trim_fraction: float = 0.1) -> Aggregator:
    """Resolve an aggregator through the plugin registry (did-you-mean on
    unknown names); an already-callable aggregator passes through."""
    if callable(name):
        return name
    return AGGREGATOR_REGISTRY.get(name)(trim_fraction=trim_fraction)


# builtin registrations — factory signature: f(*, trim_fraction, **kw)
register_aggregator("mean", lambda **kw: weighted_mean)
register_aggregator("kernel", lambda **kw: kernel_mean)
register_aggregator("median", lambda **kw: coordinate_median)
register_aggregator(
    "trimmed_mean",
    lambda *, trim_fraction=0.1, **kw: (
        lambda cp, w: trimmed_mean(cp, w, trim_fraction)))
