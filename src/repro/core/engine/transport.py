"""Transport — the compressed client-delta wire protocol (DESIGN.md §8).

Sits between ClientUpdate and Aggregator in every execution backend: clients
emit *encoded deltas* and aggregation consumes the payloads directly through
fused decompress-reduce kernels (``kernels.delta_codec``), so compressed
payloads are never materialised at full precision per client.

Codecs:

  * ``none``   — identity. ``get_transport("none")`` returns None and the
    engine keeps its historical param-space aggregation path verbatim, so
    the compiled program (and results) are bit-identical to the
    pre-transport engine. ``IdentityTransport`` is the same contract spelled
    through the protocol (used by tests).
  * ``int8``   — per-leaf int8 quantisation of the flattened delta, reusing
    the Q-KV quantiser (``models.attention.quantize_kv``: per-vector max/127
    scale). One int8 plane rides the wire (~4x uplink reduction, asymptotic
    in leaf size); the quantisation *residual is folded into the server-side
    error-feedback state* instead of being transmitted — the second Q-KV
    level for free, amortised across rounds.
  * ``int8x2`` — both Q-KV levels on the wire (primary + int8 residual,
    ``quantize_kv_residual`` verbatim): ~2x reduction, per-round error small
    enough (~1e-4 relative) that no feedback state is needed.
  * ``topk``   — magnitude top-k of the flattened delta (value + int32
    index, ``0.5/frac``x reduction) with server-side error feedback.

Error feedback (Karimireddy et al. '19, adapted to sampled stateless
clients): the paper's clients carry no state between rounds and cohorts
resample every round, so per-client residual memory is impossible — the
residual lives server-side at the *aggregate* level. The server broadcasts
it with the model (downlink already carries |x|); each client encodes
``delta_c + residual``; the new residual is the weighted compression error
``sum_c w_c (delta_c + residual) - hat``. The exact weighted-true-delta term
is directly computable in this single-process simulation; a physical
deployment would estimate it from the decoded payloads plus a residual
correction uplink — recorded in DESIGN.md §8. The residual is part of the
engine's checkpointable state (threads through the bucket scan carry and
``FedAvgTrainer.save_state``).

Compressed codecs require a *linear* aggregator (mean/kernel): the weighted
sum distributes over decode. Robust aggregators (median/trimmed_mean) need
the full client distribution and are rejected at engine construction.

The *downlink* leg (DESIGN.md §8.6, DoubleSqueeze-style bidirectional
compression — Tang et al. '19): ``DownlinkCodec`` wraps any of the codecs
above around the server broadcast. The server keeps the last broadcast
reference ``params_ref`` (exactly the model every client holds), encodes
``params_t - params_ref [+ residual]``, and clients reconstruct
``params_ref + decode(payload)`` through the fused decode-apply kernels
before local SGD — every client trains on the identical reconstructed
model, so the uplink aggregation contract is untouched (robust aggregators
included). The downlink error-feedback residual lives server-side next to
``params_ref``; both are engine state (``RoundEngine.downlink_state``),
thread the K-bucket scan carry and checkpoint with ``save_state``.
``downlink="none"`` keeps the historical broadcast (and compiled program)
bit-for-bit.
"""
from __future__ import annotations

import copy
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# literal reuse of the Q-KV quantisation scheme (two-level int8 + per-vector
# f32 scales — models/attention.py §Perf Q-KV); pure jnp, no layer deps
from repro.api.registries import TRANSPORT_REGISTRY, register_transport
from repro.core.engine.backends.base import axes_size as _axes_size
from repro.models.attention import quantize_kv, quantize_kv_residual

PyTree = Any

TRANSPORTS = ("none", "int8", "int8x2", "topk")   # builtins



def _weighted_true_sum(deltas, weights):
    """sum_c w_c delta_c in f32 — the EF truth term (an einsum per leaf; the
    (N, ...) stack already exists, nothing new is materialised)."""
    w32 = weights.astype(jnp.float32)
    return [jnp.einsum("c,c...->...", w32, d) for d in deltas]


class Transport:
    """Protocol. ``signature()`` keys the engine's compile cache; ``encode``
    runs per client (vmapped on parallel backends, inside the client scan on
    sequential ones); ``reduce`` consumes the stacked payloads fused."""

    name: str = "base"
    error_feedback: bool = False
    #: per-client error-feedback slot count (fixed cohorts, DESIGN.md §9.3):
    #: None = server-aggregate residual (stateless sampled clients); an int N
    #: = one residual slot per cohort slot, valid only when slot j maps to
    #: the same client every round (``ClientSampler.stateful_cohort``).
    ef_slots: Optional[int] = None

    # -- identity / compile-cache -------------------------------------
    def signature(self) -> Tuple:
        """Hashable codec signature, mixed into the AOT registry key."""
        return (self.name, self.error_feedback, self.ef_slots)

    # -- cohort binding -------------------------------------------------
    def with_ef_slots(self, n: int) -> "Transport":
        """A copy carrying per-client error feedback for an ``n``-client
        fixed cohort; identity for codecs without feedback state."""
        if not self.error_feedback:
            return self
        t = copy.copy(self)
        t.ef_slots = int(n)
        return t

    # -- mesh binding ---------------------------------------------------
    def with_mesh(self, mesh, client_axes: Optional[Sequence[str]],
                  reduce_tiers=None):
        """Backend hook: a copy bound to the mesh so ``reduce`` can route
        through the client-sharded decompress-reduce kernel.
        ``reduce_tiers`` selects the hierarchical grouped all-reduce
        (DESIGN.md §11) instead of the flat psum."""
        t = copy.copy(self)
        t._mesh = mesh
        t._client_axes = tuple(client_axes) if client_axes else None
        t._reduce_tiers = (tuple(tuple(tier) for tier in reduce_tiers)
                           if reduce_tiers else None)
        return t

    def _mesh_axes(self):
        return getattr(self, "_mesh", None), getattr(self, "_client_axes", None)

    def _tiers(self):
        return getattr(self, "_reduce_tiers", None)

    # -- state ----------------------------------------------------------
    def init_state(self, params: PyTree):
        if not self.error_feedback:
            return ()
        lead = (self.ef_slots,) if self.ef_slots else ()
        return jax.tree.map(
            lambda p: jnp.zeros(lead + tuple(p.shape), jnp.float32), params)

    # -- codec (per-leaf-list payloads, leaves in tree.flatten order) ----
    def encode(self, delta: PyTree):
        raise NotImplementedError

    def decode(self, payload, like: PyTree) -> PyTree:
        raise NotImplementedError

    def reduce(self, payloads, weights: jnp.ndarray, like: PyTree) -> PyTree:
        """Stacked payloads (leading client axis) -> weighted-sum delta
        pytree, via the fused decompress-reduce kernels."""
        raise NotImplementedError

    def decode_apply(self, payload, ref: PyTree) -> PyTree:
        """``ref + decode(payload)`` — the downlink reconstruction every
        client runs (DESIGN.md §8.6). Default: decode then add; codecs
        override with the fused decode-apply kernels so the dense f32
        delta is never materialised."""
        dec = self.decode(payload, like=ref)
        return jax.tree.map(
            lambda r, d: (r.astype(jnp.float32) + d).astype(r.dtype),
            ref, dec)

    # -- wire accounting -------------------------------------------------
    def encoded_bits(self, params: PyTree) -> int:
        """Uplink bits one client pays per round for this codec."""
        raise NotImplementedError

    def compression_ratio(self, params: PyTree,
                          bits_per_param: int = 32) -> float:
        full = bits_per_param * sum(int(l.size)
                                    for l in jax.tree.leaves(params))
        return full / float(self.encoded_bits(params))

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        """Asymptotic ratio (scale/metadata overhead -> 0 at model scale);
        used by analytic benches that have no concrete param tree."""
        raise NotImplementedError

    # -- the round-core entry point --------------------------------------
    def aggregate(self, aggregator, params: PyTree, client_stack: PyTree,
                  weights: jnp.ndarray, state):
        """(params, client-stacked params (N, ...), weights (N,), state) ->
        (aggregate pytree, new state). Compressed codecs ignore the
        aggregator (validated linear upstream) and work in delta space."""
        del aggregator
        p32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        deltas = jax.tree.map(lambda cp, p: cp.astype(jnp.float32) - p[None],
                              client_stack, p32)
        if self.error_feedback:
            # compensate: per-client slots carry their own residual (fixed
            # cohorts), the aggregate residual is broadcast to every client
            deltas = (jax.tree.map(jnp.add, deltas, state) if self.ef_slots
                      else jax.tree.map(lambda d, r: d + r[None], deltas,
                                        state))
        payloads = jax.vmap(self.encode)(deltas)
        hat = self.reduce(payloads, weights, like=params)
        if not self.error_feedback:
            new_state = state
        elif self.ef_slots:
            # per-client residual: each slot keeps ITS OWN compression error
            # (Karimireddy et al. '19, the stateful-client original) — no
            # weighted-truth term, no cross-client mixing. The residual
            # NEEDS the per-client decode, so this mode pays decode twice
            # (once fused inside reduce, once here); hat deliberately stays
            # on the fused reduce so the wire-aggregation program — and its
            # numerics — are identical across EF modes (the parity
            # contracts in tests/test_sampling.py key on this). Decode is
            # O(N*M) elementwise, dwarfed by the K local-SGD steps.
            decoded = jax.vmap(lambda pl: self.decode(pl, like=params))(
                payloads)
            new_state = jax.tree.map(jnp.subtract, deltas, decoded)
        else:
            true = _weighted_true_sum(jax.tree.leaves(deltas), weights)
            new_state = jax.tree.unflatten(
                jax.tree.structure(params),
                [t - h for t, h in zip(true, jax.tree.leaves(hat))])
        aggregate = jax.tree.map(
            lambda p, h: (p.astype(jnp.float32) + h).astype(p.dtype),
            params, hat)
        return aggregate, new_state

    def aggregate_slab(self, params: PyTree, client_stack: PyTree,
                       weights: jnp.ndarray, state):
        """One C-client slab's contribution to a streaming round (DESIGN.md
        §11): the delta/EF-compensate/encode/reduce pipeline of
        ``aggregate`` verbatim, but instead of applying the weighted-sum
        delta it RETURNS the partials for the caller to fold into its
        running accumulators.

        ``weights`` are the slab's slice of the global round weights (they
        sum to 1 over the whole cohort, NOT over the slab), so the partial
        sums compose by plain addition. ``state`` is this slab's EF: the
        per-client residual slice for slotted EF, or the round-frozen
        aggregate residual (read-only here — the finalize step derives the
        new one as sum(true) - sum(hat), matching ``aggregate`` exactly).

        Returns ``(hat, true, new_state)``: ``hat`` the f32 weighted-sum of
        decoded deltas, ``true`` the f32 weighted-sum of raw deltas
        (aggregate-EF codecs only, else ``()``), ``new_state`` the slab's
        updated per-client residuals (slotted EF) or ``state`` unchanged."""
        p32 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        deltas = jax.tree.map(lambda cp, p: cp.astype(jnp.float32) - p[None],
                              client_stack, p32)
        if self.error_feedback:
            deltas = (jax.tree.map(jnp.add, deltas, state) if self.ef_slots
                      else jax.tree.map(lambda d, r: d + r[None], deltas,
                                        state))
        payloads = jax.vmap(self.encode)(deltas)
        hat = self.reduce(payloads, weights, like=params)
        if not self.error_feedback:
            return hat, (), state
        if self.ef_slots:
            decoded = jax.vmap(lambda pl: self.decode(pl, like=params))(
                payloads)
            return hat, (), jax.tree.map(jnp.subtract, deltas, decoded)
        true = _weighted_true_sum(jax.tree.leaves(deltas), weights)
        true_tree = jax.tree.unflatten(jax.tree.structure(params), list(true))
        return hat, true_tree, state


class IdentityTransport(Transport):
    """The degenerate codec: payloads ARE the client params; aggregation
    delegates to the configured Aggregator verbatim (robust ones included),
    so the round math is exactly the transport-less engine's."""

    name = "none"

    def encode(self, delta):
        return jax.tree.leaves(delta)

    def decode(self, payload, like):
        return jax.tree.unflatten(jax.tree.structure(like), list(payload))

    def encoded_bits(self, params):
        return 32 * sum(int(l.size) for l in jax.tree.leaves(params))

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        return 1.0

    def aggregate(self, aggregator, params, client_stack, weights, state):
        return aggregator(client_stack, weights), state


class Int8Transport(Transport):
    """Q-KV int8 codec on the flattened per-leaf delta.

    ``levels=1`` (the ``int8`` transport): one int8 plane + one f32 scale
    per leaf on the wire; the quantisation residual is recovered through the
    server-side error-feedback state across rounds. ``levels=2``
    (``int8x2``): ``quantize_kv_residual`` verbatim — primary + residual
    int8 planes with their scales, no feedback state needed.
    """

    name = "int8"

    def __init__(self, levels: int = 1, error_feedback: bool = True):
        if levels not in (1, 2):
            raise ValueError(f"int8 transport levels must be 1 or 2: {levels}")
        self.levels = levels
        self.error_feedback = error_feedback
        if levels == 2:
            self.name = "int8x2"

    def signature(self):
        return (self.name, self.levels, self.error_feedback, self.ef_slots)

    def encode(self, delta):
        out = []
        for leaf in jax.tree.leaves(delta):
            flat = leaf.astype(jnp.float32).reshape(-1)
            if self.levels == 1:
                q, s = quantize_kv(flat)
                out.append({"q": q, "s": s})
            else:
                q, s, qr, rs = quantize_kv_residual(flat)
                out.append({"q": q, "s": s, "qr": qr, "rs": rs})
        return out

    def decode(self, payload, like):
        leaves, treedef = jax.tree.flatten(like)
        dec = []
        for pl, leaf in zip(payload, leaves):
            x = pl["q"].astype(jnp.float32) * pl["s"]
            if self.levels == 2:
                x = x + pl["qr"].astype(jnp.float32) * pl["rs"]
            dec.append(x.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, dec)

    def reduce(self, payloads, weights, like):
        from repro.kernels import ops as kops
        mesh, axes = self._mesh_axes()
        n = weights.shape[0]
        sharded = (mesh is not None and axes
                   and n % _axes_size(mesh, axes) == 0)
        leaves, treedef = jax.tree.flatten(like)
        out = []
        for pl, leaf in zip(payloads, leaves):
            w1 = weights.astype(jnp.float32) * pl["s"][:, 0]
            wr = (weights.astype(jnp.float32) * pl["rs"][:, 0]
                  if self.levels == 2 else None)
            qr = pl["qr"] if self.levels == 2 else None
            if sharded:
                flat = kops.int8_delta_reduce_sharded(
                    pl["q"], w1, qr, wr, mesh=mesh, client_axes=axes,
                    reduce_tiers=self._tiers())
            else:
                flat = kops.int8_delta_reduce(pl["q"], w1, qr, wr)
            out.append(flat.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, out)

    def decode_apply(self, payload, ref):
        from repro.kernels import ops as kops
        mesh, axes = self._mesh_axes()
        leaves, treedef = jax.tree.flatten(ref)
        out = []
        for pl, leaf in zip(payload, leaves):
            flat = leaf.reshape(-1)
            qr = pl["qr"] if self.levels == 2 else None
            rs = pl["rs"] if self.levels == 2 else None
            if (mesh is not None and axes
                    and flat.shape[0] % _axes_size(mesh, axes) == 0):
                rec = kops.int8_delta_apply_sharded(flat, pl["q"], pl["s"],
                                                    qr, rs, mesh=mesh,
                                                    axes=axes)
            else:
                rec = kops.int8_delta_apply(flat, pl["q"], pl["s"], qr, rs)
            out.append(rec.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, out)

    def encoded_bits(self, params):
        bits = 0
        for leaf in jax.tree.leaves(params):
            bits += self.levels * (8 * int(leaf.size) + 32)   # planes + scales
        return bits

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        return bits_per_param / (8.0 * self.levels)


class TopKTransport(Transport):
    """Magnitude top-k of the flattened per-leaf delta (f32 value + int32
    index per kept coordinate) with server-side error feedback — the
    residual carries everything the sparsifier dropped into later rounds."""

    name = "topk"

    def __init__(self, frac: float = 0.1, error_feedback: bool = True):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk frac must be in (0, 1]: {frac}")
        self.frac = float(frac)
        self.error_feedback = error_feedback

    def signature(self):
        return (self.name, self.frac, self.error_feedback, self.ef_slots)

    def _k(self, size: int) -> int:
        # clamped to [1, size]: ceil can round below 1 on tiny leaves
        # (k == 0 would silently drop the leaf from the wire) and the index
        # payload is invalid past the leaf itself (lax.top_k rejects
        # k > size). Empty leaves ship an empty payload (k == 0).
        return min(size, max(1, int(math.ceil(self.frac * size))))

    def encode(self, delta):
        out = []
        for leaf in jax.tree.leaves(delta):
            flat = leaf.astype(jnp.float32).reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), self._k(flat.shape[0]))
            out.append({"v": jnp.take(flat, idx), "i": idx.astype(jnp.int32)})
        return out

    def decode(self, payload, like):
        leaves, treedef = jax.tree.flatten(like)
        dec = []
        for pl, leaf in zip(payload, leaves):
            flat = jnp.zeros((int(leaf.size),), jnp.float32)
            dec.append(flat.at[pl["i"]].set(pl["v"]).reshape(leaf.shape))
        return jax.tree.unflatten(treedef, dec)

    def reduce(self, payloads, weights, like):
        from repro.kernels import ops as kops
        mesh, axes = self._mesh_axes()
        n = weights.shape[0]
        leaves, treedef = jax.tree.flatten(like)
        out = []
        for pl, leaf in zip(payloads, leaves):
            size = int(leaf.size)
            # sharded only where the Mosaic formulation itself applies:
            # the per-shard partial IS the one-hot kernel (ops gates it
            # off for large payloads in interpret mode)
            if (mesh is not None and axes
                    and n % _axes_size(mesh, axes) == 0
                    and kops.mosaic_scatter_ok(int(pl["v"].size), size)):
                flat = kops.topk_delta_reduce_sharded(
                    pl["v"], pl["i"], weights, size, mesh=mesh,
                    client_axes=axes, reduce_tiers=self._tiers())
            else:
                flat = kops.topk_delta_reduce(pl["v"], pl["i"], weights,
                                              size)
            out.append(flat.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, out)

    def decode_apply(self, payload, ref):
        from repro.kernels import ops as kops
        leaves, treedef = jax.tree.flatten(ref)
        out = [kops.topk_delta_apply(leaf.reshape(-1), pl["v"], pl["i"]
                                     ).reshape(leaf.shape)
               for pl, leaf in zip(payload, leaves)]
        return jax.tree.unflatten(treedef, out)

    def encoded_bits(self, params):
        bits = 0
        for leaf in jax.tree.leaves(params):
            bits += 64 * self._k(int(leaf.size))         # f32 value + i32 idx
        return bits

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        return bits_per_param / (64.0 * self.frac)


REF_STORES = ("f32", "q8")


def _q8_encode(x) -> dict:
    """Params-shaped f32-equivalent leaf -> two-level int8 store leaf
    (DESIGN.md §10): Q-KV primary + residual planes over the flattened
    leaf, per-leaf f32 scales. ~2 bytes/param held instead of 4, worst-case
    value error ~max|x|/127^2 (~6e-5 relative) — the key names are
    prefixed so a store leaf can never be confused with a params subtree.
    """
    q, s, qr, rs = quantize_kv_residual(x.astype(jnp.float32).reshape(-1))
    return {"q8_q": q, "q8_s": s, "q8_qr": qr, "q8_rs": rs}


def _q8_decode(d: dict, like) -> jnp.ndarray:
    x = (d["q8_q"].astype(jnp.float32) * d["q8_s"]
         + d["q8_qr"].astype(jnp.float32) * d["q8_rs"])
    return x.reshape(like.shape).astype(like.dtype)


def _is_q8(x) -> bool:
    return isinstance(x, dict) and "q8_q" in x


class DownlinkCodec:
    """Server->client broadcast compression (DESIGN.md §8.6).

    Wraps one of the delta codecs above around the broadcast leg. State
    machine (all server-side, engine-owned):

      * ``params_ref`` — the last broadcast reconstruction, i.e. exactly
        the model every client currently holds (round 0: the init params,
        which clients received at enrolment).
      * ``residual``   — the downlink error-feedback buffer (codecs with
        ``error_feedback``; int8's untransmitted second level, top-k's
        dropped coordinates).

    Per round: ``payload = enc(params_t - params_ref + residual)``; every
    client reconstructs ``recon = params_ref + dec(payload)`` (the fused
    decode-apply kernels) and runs local SGD from ``recon``; the new
    reference IS ``recon`` and ``residual' = (delta + residual) -
    dec(payload)``. Because all clients reconstruct identically, the
    uplink aggregation contract is unchanged — the round core simply runs
    on ``recon`` instead of ``params_t`` (robust aggregators included).

    ``encode_broadcast`` is the split-phase entry point the fused round
    cores use (DESIGN.md §10): it returns the wire payload next to the f32
    reference view so clients can reconstruct *lazily* inside their own
    first forward (``decode_into``) instead of the engine materialising the
    recon tree up front; ``broadcast`` composes the two for callers that
    want the eager tree (tests, sequential cores).

    ``ref_store="q8"`` keeps ``params_ref``/``residual`` as two-level-int8
    store leaves (``_q8_encode``) instead of f32-equivalent trees — half
    the server-side bytes held; the reference is dequantised on demand and
    the next reference re-quantises the reconstruction. The quantisation
    error lives *inside* the ref/recon pair coherently (clients and server
    see the same dequantised view), so the EF algebra is unchanged.

    On EF codecs the server pays one extra decode per round to form the
    residual (dec is recomputed next to the fused apply — same f32 ops, so
    the residual is exact w.r.t. the shipped payload); clients only ever
    run the fused apply. Decode is O(|x|) elementwise, dwarfed by the K
    local-SGD steps.
    """

    def __init__(self, codec: Transport, ref_store: str = "f32"):
        if codec is None or getattr(codec, "name", "none") == "none":
            raise ValueError("DownlinkCodec wraps a real codec; use "
                             "downlink='none' for the uncompressed "
                             "broadcast")
        if ref_store not in REF_STORES:
            raise ValueError(f"downlink ref_store must be one of "
                             f"{REF_STORES}: {ref_store!r}")
        self.codec = codec
        self.name = codec.name
        self.error_feedback = bool(codec.error_feedback)
        self.ref_store = ref_store

    # -- identity / compile-cache -------------------------------------
    def signature(self) -> Tuple:
        sig = ("downlink",) + tuple(self.codec.signature())
        if self.ref_store != "f32":
            sig = sig + ("ref:" + self.ref_store,)
        return sig

    # -- mesh binding ---------------------------------------------------
    def with_mesh(self, mesh, client_axes, reduce_tiers=None):
        t = copy.copy(self)
        # the server-side eager decode (encode_broadcast) routes through
        # the mesh-sharded decode-apply kernel; the client-side lazy decode
        # (decode_into) runs inside the vmapped client trace where a
        # shard_map cannot nest — it keeps the unbound elementwise kernel
        # (bitwise-identical output) and GSPMD places it
        t._unbound = self.codec
        t.codec = self.codec.with_mesh(mesh, client_axes, reduce_tiers)
        return t

    # -- quantised ref store -------------------------------------------
    def store_tree(self, tree: PyTree) -> PyTree:
        """Params-shaped f32-equivalent tree -> stored representation."""
        if self.ref_store == "f32":
            return tree
        return jax.tree.map(_q8_encode, tree)

    def load_tree(self, stored: PyTree, like: PyTree) -> PyTree:
        """Stored representation -> params-shaped tree (dequantise on
        demand; ``like`` supplies shapes/dtypes)."""
        if self.ref_store == "f32":
            return stored
        return jax.tree.map(_q8_decode, stored, like,
                            is_leaf=lambda x: _is_q8(x))

    def state_bytes(self, state) -> int:
        """Server-side bytes held by ref + residual (bench accounting)."""
        return sum(int(l.size) * l.dtype.itemsize
                   for l in jax.tree.leaves(state))

    # -- state ----------------------------------------------------------
    def init_state(self, params: PyTree):
        ref = self.store_tree(jax.tree.map(
            lambda p: jnp.asarray(p), params))
        res = (self.store_tree(jax.tree.map(
            lambda p: jnp.zeros(tuple(p.shape), jnp.float32), params))
            if self.error_feedback else ())
        return {"ref": ref, "res": res}

    # -- the round entry points ------------------------------------------
    def encode_broadcast(self, params: PyTree, state):
        """(server params, state) -> (ref, payload, recon, new state).

        ``ref`` is the f32-equivalent reference view (dequantised for q8
        stores) and ``payload`` the encoded delta — together the lazy
        client-side reconstruction input (``decode_into``); ``recon`` is
        the same reconstruction computed eagerly for the server side
        (aggregate target + next reference). Under jit the eager and lazy
        decodes are identical elementwise programs, so XLA CSEs them when
        both land in one round core."""
        ref = self.load_tree(state["ref"], like=params)
        res = (self.load_tree(state["res"], like=params)
               if self.error_feedback else ())
        delta = jax.tree.map(
            lambda p, r: p.astype(jnp.float32) - r.astype(jnp.float32),
            params, ref)
        if self.error_feedback:
            delta = jax.tree.map(jnp.add, delta, res)
        payload = self.codec.encode(delta)
        recon = self.codec.decode_apply(payload, ref)
        if self.error_feedback:
            dec = self.codec.decode(payload, like=params)
            res = self.store_tree(jax.tree.map(jnp.subtract, delta, dec))
        return ref, payload, recon, {"ref": self.store_tree(recon),
                                     "res": res}

    def decode_into(self, payload, ref: PyTree) -> PyTree:
        """Client-side lazy reconstruction: ``ref + dec(payload)`` through
        the fused decode-apply kernels, run inside ClientUpdate's own
        trace (DESIGN.md §10) instead of on an engine-materialised tree."""
        return getattr(self, "_unbound", self.codec).decode_apply(payload,
                                                                  ref)

    def broadcast(self, params: PyTree, state):
        """(server params, state) -> (client reconstruction, new state)."""
        _, _, recon, new_state = self.encode_broadcast(params, state)
        return recon, new_state

    # -- wire accounting -------------------------------------------------
    def encoded_bits(self, params: PyTree) -> int:
        return self.codec.encoded_bits(params)

    def compression_ratio(self, params: PyTree,
                          bits_per_param: int = 32) -> float:
        return self.codec.compression_ratio(params, bits_per_param)

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        return self.codec.nominal_ratio(bits_per_param)


class AdaptiveDownlinkCodec(DownlinkCodec):
    """Per-round adaptive broadcast codec (DESIGN.md §10).

    Wraps the two-level int8 quantiser with a traced per-round policy on
    the EF-corrected delta:

      * level 0 — *skip*: ``|delta|`` is near zero relative to ``|ref|``
        (plateaued schedule, converged model): ship nothing; the whole
        delta folds into the EF residual and clients keep training on the
        previous reconstruction.
      * level 2 — *boost*: the EF residual norm spikes relative to the
        delta norm (compression error piling up faster than the model
        moves): ship both int8 planes (``int8x2``) to drain the residual.
      * level 1 — the default single-plane ``int8`` broadcast.

    The decision is data-dependent but shape-static: all planes are always
    computed, levels select via ``jnp.where`` masks so one compiled
    program covers every round. The chosen level rides out of the round
    core as an int32 scalar per round; ``FedAvgTrainer`` charges
    ``RuntimeModel`` per-level (level 0 pays zero broadcast bits).
    Error feedback is structural here — a skipped round's delta *must*
    survive in the residual — so the codec always runs with EF on.
    """

    def __init__(self, *, skip_rtol: float = 1e-3, boost_rtol: float = 0.5,
                 ref_store: str = "f32"):
        super().__init__(Int8Transport(levels=2, error_feedback=True),
                         ref_store=ref_store)
        self.name = "adaptive"
        self.skip_rtol = float(skip_rtol)
        self.boost_rtol = float(boost_rtol)

    def signature(self) -> Tuple:
        sig = ("downlink", "adaptive", self.skip_rtol, self.boost_rtol)
        if self.ref_store != "f32":
            sig = sig + ("ref:" + self.ref_store,)
        return sig

    @staticmethod
    def _norm(tree) -> jnp.ndarray:
        leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
                  for l in jax.tree.leaves(tree)]
        return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())

    def _level(self, delta, ref, res) -> jnp.ndarray:
        nd, nref, nres = self._norm(delta), self._norm(ref), self._norm(res)
        ship = nd > self.skip_rtol * (nref + 1e-12)
        boost = nres > self.boost_rtol * (nd + 1e-12)
        return jnp.where(ship, jnp.where(boost, 2, 1), 0).astype(jnp.int32)

    def encode_broadcast(self, params: PyTree, state):
        """Returns ``(ref, payload, recon, new_state, level)`` — one more
        element than the base codec: the traced per-round level."""
        ref = self.load_tree(state["ref"], like=params)
        res32 = self.load_tree(state["res"], like=params)
        delta = jax.tree.map(
            lambda p, r: p.astype(jnp.float32) - r.astype(jnp.float32),
            params, ref)
        # policy inputs: the raw round delta vs the accumulated residual
        level = self._level(delta, ref, res32)
        delta = jax.tree.map(jnp.add, delta, res32)
        payload = self.codec.encode(delta)   # both planes, always computed
        payload = [dict(pl, lvl=level) for pl in payload]
        recon = self.decode_into(payload, ref)
        dec = self._decode(payload, like=params)
        res = self.store_tree(jax.tree.map(jnp.subtract, delta, dec))
        return ref, payload, recon, {"ref": self.store_tree(recon),
                                     "res": res}, level

    def _decode(self, payload, like: PyTree) -> PyTree:
        """Level-masked dequantise: level 0 decodes to zero (nothing on
        the wire), level 1 the primary plane, level 2 both planes."""
        leaves, treedef = jax.tree.flatten(like)
        dec = []
        for pl, leaf in zip(payload, leaves):
            lvl = pl["lvl"]
            x = jnp.where(lvl >= 1,
                          pl["q"].astype(jnp.float32) * pl["s"], 0.0)
            x = x + jnp.where(lvl >= 2,
                              pl["qr"].astype(jnp.float32) * pl["rs"], 0.0)
            dec.append(x.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, dec)

    def decode_into(self, payload, ref: PyTree) -> PyTree:
        dec = self._decode(payload, like=ref)
        return jax.tree.map(
            lambda r, d: (r.astype(jnp.float32) + d).astype(r.dtype),
            ref, dec)

    def broadcast(self, params: PyTree, state):
        _, _, recon, new_state, _ = self.encode_broadcast(params, state)
        return recon, new_state

    # -- wire accounting: nominal = the default level-1 broadcast ---------
    def _level_bits(self, params: PyTree, level: int) -> int:
        if level <= 0:
            return 0
        bits = 0
        for leaf in jax.tree.leaves(params):
            bits += level * (8 * int(leaf.size) + 32)    # planes + scales
        return bits

    def encoded_bits(self, params: PyTree) -> int:
        return self._level_bits(params, 1)

    def compression_ratio(self, params: PyTree,
                          bits_per_param: int = 32) -> float:
        full = bits_per_param * sum(int(l.size)
                                    for l in jax.tree.leaves(params))
        return full / float(self.encoded_bits(params))

    def level_ratios(self, params: PyTree,
                     bits_per_param: int = 32) -> dict:
        """{level: compression ratio} for RuntimeModel's per-level wire
        charging (level 0 ships nothing and is charged as such)."""
        full = bits_per_param * sum(int(l.size)
                                    for l in jax.tree.leaves(params))
        return {lvl: full / float(self._level_bits(params, lvl))
                for lvl in (1, 2)}

    def nominal_ratio(self, bits_per_param: int = 32) -> float:
        return bits_per_param / 8.0


def get_downlink(name, *, topk_frac: float = 0.1,
                 ref_store: str = "f32") -> Optional[DownlinkCodec]:
    """Resolve the broadcast codec through the same transport registry
    (any registered codec doubles as a downlink codec; downlink-only
    codecs like ``adaptive`` resolve here exclusively). ``None``/``"none"``
    -> None: the engine keeps the historical uncompressed broadcast (and
    its compiled program) bit-for-bit."""
    if name is None or isinstance(name, DownlinkCodec):
        return name
    codec = (name if isinstance(name, Transport)
             else TRANSPORT_REGISTRY.get(name)(topk_frac=topk_frac,
                                               ref_store=ref_store))
    if codec is None:                              # registry "none"
        return None
    if isinstance(codec, DownlinkCodec):           # e.g. "adaptive"
        return codec
    return DownlinkCodec(codec, ref_store=ref_store)


def get_transport(name, *, topk_frac: float = 0.1) -> Optional[Transport]:
    """Resolve a codec through the plugin registry. ``None``/``"none"`` ->
    None: the engine keeps its historical (bit-identical) param-space path.
    A ``Transport`` instance passes through. Unknown names get did-you-mean
    errors from the registry; downlink-only codecs are rejected."""
    if name is None:
        return None
    if isinstance(name, DownlinkCodec):
        raise ValueError(f"{name.name!r} is a downlink-only codec; it is "
                         f"valid for transport.downlink, not "
                         f"transport.name")
    if isinstance(name, Transport):
        return name
    codec = TRANSPORT_REGISTRY.get(name)(topk_frac=topk_frac)
    if isinstance(codec, DownlinkCodec):
        raise ValueError(f"{name!r} is a downlink-only codec; it is valid "
                         f"for transport.downlink, not transport.name")
    return codec


# builtin registrations — factory signature: f(*, topk_frac, **kw)
register_transport("none", lambda **kw: None)
register_transport("int8",
                   lambda **kw: Int8Transport(levels=1, error_feedback=True))
register_transport("int8x2",
                   lambda **kw: Int8Transport(levels=2, error_feedback=False))
register_transport(
    "topk",
    lambda *, topk_frac=0.1, **kw: TopKTransport(frac=topk_frac,
                                                 error_feedback=True))
register_transport(
    "adaptive",
    lambda *, ref_store="f32", **kw: AdaptiveDownlinkCodec(
        ref_store=ref_store))
