"""ClientSampler — who participates in each federated round (DESIGN.md §9.3).

Algorithm 1 line 3 ("sample C_r uniformly") was hard-coded in the data
pipeline; partial-participation regimes whose convergence depends on the
sampling scheme (Li et al., *On the Convergence of FedAvg on Non-IID
Data*) could not be expressed. A ``ClientSampler`` owns both decisions a
round opens with: *which* clients run, and with what aggregation *weights*.

Samplers (registered in ``repro.api.registries``):

  * ``uniform``      — without-replacement uniform draw. Consumes EXACTLY
    the rng stream of the historical ``pipeline.sample_clients`` call, so
    the default configuration is bitwise-identical to every prior PR.
  * ``weighted``     — draw probability proportional to client dataset
    size (importance sampling for heavy-tailed client populations).
  * ``fixed_cohort`` — the same cohort every round, in a stable order:
    cross-silo FL, where clients are stateful organisations. Declares
    ``stateful_cohort``, which switches transport error feedback from the
    server-aggregate residual to per-client residual slots
    (``Transport.with_ef_slots``; slot j is always cohort[j]).
  * ``availability`` — per-round participation mask: each client is online
    with probability ``p`` this round; the cohort is drawn from the online
    set. If fewer than ``n`` clients are online the cohort is padded with
    offline clients at aggregation weight 0 (shape stability for the jitted
    round; zero weight = they contribute nothing to *linear* aggregators —
    combining availability shortfall with median/trimmed_mean is rejected
    by spec validation). Populations up to ``DENSE_MAX`` keep the
    historical dense Bernoulli draw (bitwise rng-stream compat); beyond it
    the draw switches to O(cohort) rejection sampling — no per-client
    array is ever materialised (DESIGN.md §11).
  * ``population`` — population-scale diurnal availability (DESIGN.md
    §11): client ids live in a virtual ``population``-sized space (10^6+),
    each id's timezone phase is a splitmix64 hash of the id (zero stored
    state), and per-round availability follows a cosine day curve between
    ``base`` and ``peak`` sampled by O(cohort) rejection.

The sampler runs on the host, inside the bucket builder (possibly on the
prefetch thread — requests are FIFO on one rng, so results depend only on
(rng state, submission order), never on timing).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.api.registries import SAMPLER_REGISTRY, register_sampler
from repro.data.pipeline import client_weights as _size_weights
from repro.data.synthetic import FederatedData


def _stable_unique(a: np.ndarray) -> np.ndarray:
    """Deduplicate keeping first-occurrence order (np.unique sorts)."""
    _, idx = np.unique(a, return_index=True)
    return a[np.sort(idx)]


def splitmix64(ids: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser: int ids -> u64 hashes. The O(1)
    per-client state trick (DESIGN.md §11): any per-client trait (timezone
    phase) is a pure function of the id, so a 10^6+ population carries no
    per-client arrays."""
    with np.errstate(over="ignore"):
        z = ids.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def _hash_unit(ids: np.ndarray) -> np.ndarray:
    """ids -> deterministic floats in [0, 1)."""
    return splitmix64(ids).astype(np.float64) / float(2 ** 64)


class ClientSampler:
    """Protocol. ``round(rng, data, n, round_idx)`` -> (ids (n,), weights
    (n,) f32 summing to 1). ``round_idx`` is the absolute 1-based round
    index (schedule-stable across checkpoint resume); samplers that do not
    depend on it must ignore it."""

    name: str = "base"
    #: True => the cohort is fixed for the whole run and slot j always maps
    #: to the same client — per-client transport error feedback is sound.
    stateful_cohort: bool = False
    #: True => the sampler communicates participation through the weight
    #: vector (zero-weight slots), so aggregation must respect weights —
    #: the trainer rejects weight-ignoring (robust) aggregators.
    needs_weighted_aggregation: bool = False

    def sample(self, rng: np.random.Generator, data: FederatedData, n: int,
               round_idx: Optional[int] = None) -> np.ndarray:
        raise NotImplementedError

    def weights(self, data: FederatedData, ids: np.ndarray) -> np.ndarray:
        return _size_weights(data, ids)

    def round(self, rng: np.random.Generator, data: FederatedData, n: int,
              round_idx: Optional[int] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        ids = self.sample(rng, data, n, round_idx)
        return ids, self.weights(data, ids)


class UniformSampler(ClientSampler):
    """Uniform without replacement — draw-for-draw the historical stream
    (delegates to the historical draw itself, so the bitwise contract is
    true by construction)."""

    name = "uniform"

    def sample(self, rng, data, n, round_idx=None):
        from repro.data.pipeline import sample_clients
        return sample_clients(rng, data, n)


class WeightedSampler(ClientSampler):
    """Inclusion probability proportional to client dataset size."""

    name = "weighted"

    def sample(self, rng, data, n, round_idx=None):
        sizes = np.array([len(y) for y in data.client_y], dtype=np.float64)
        return rng.choice(data.num_clients, size=min(n, data.num_clients),
                          replace=False, p=sizes / sizes.sum())


class FixedCohortSampler(ClientSampler):
    """The same clients, in the same slot order, every round (cross-silo).

    ``cohort=None`` defaults to clients ``0..n-1``. Consumes no rng, so two
    runs differing only in cohort membership share their batch-sampling
    stream per slot."""

    name = "fixed_cohort"
    stateful_cohort = True

    def __init__(self, cohort: Optional[Sequence[int]] = None):
        self.cohort = None if cohort is None else tuple(int(c) for c in cohort)

    def sample(self, rng, data, n, round_idx=None):
        cohort = self.cohort if self.cohort is not None else tuple(range(n))
        if len(cohort) != n:
            raise ValueError(f"fixed cohort has {len(cohort)} clients, "
                             f"round needs {n}")
        bad = [c for c in cohort if not 0 <= c < data.num_clients]
        if bad:
            raise ValueError(f"cohort ids {bad} out of range "
                             f"[0, {data.num_clients})")
        return np.asarray(cohort, dtype=np.int64)


class AvailabilitySampler(ClientSampler):
    """Bernoulli(p) per-round participation mask (cross-device churn).

    Shortfall policy: when fewer than ``n`` clients are online, offline
    clients pad the cohort at weight 0 so the jitted round keeps its shape.

    Degenerate-round guard: a round with NOBODY online re-draws as a plain
    uniform round (documented deviation — the jitted schedule cannot skip
    a round in this simulation). Padding the whole cohort at weight 0
    instead would make the weighted mean a 0/0 and poison the params with
    NaN, which is why the guard also covers the weight normalisation in
    the shortfall branch (all-empty online datasets fall back to uniform
    weights over the online set). Regression-tested at ``prob≈0`` in
    ``tests/test_sampling.py``."""

    name = "availability"
    needs_weighted_aggregation = True   # shortfall padding rides zero weights

    #: populations at or below this keep the historical dense Bernoulli
    #: draw — its rng stream is bitwise pinned by existing runs/tests.
    #: Above it, ``round`` switches to the O(cohort) rejection path: no
    #: O(num_clients) array is ever allocated (DESIGN.md §11).
    DENSE_MAX = 65536

    def __init__(self, prob: float = 0.9):
        if not 0.0 < prob <= 1.0:
            raise ValueError(f"availability prob must be in (0, 1]: {prob}")
        self.prob = float(prob)

    def round(self, rng, data, n, round_idx=None):
        n = min(n, data.num_clients)
        if data.num_clients > self.DENSE_MAX:
            return self._sparse_round(rng, data, n)
        online = np.flatnonzero(rng.random(data.num_clients) < self.prob)
        if len(online) == 0:              # all-offline: re-draw uniformly
            ids = rng.choice(data.num_clients, size=n, replace=False)
            return ids, _size_weights(data, ids)
        if len(online) >= n:
            ids = rng.choice(online, size=n, replace=False)
            return ids, _size_weights(data, ids)
        offline = np.setdiff1d(np.arange(data.num_clients), online,
                               assume_unique=True)
        fill = rng.choice(offline, size=n - len(online), replace=False)
        ids = np.concatenate([online, fill])
        return ids, self._shortfall_weights(data, ids, len(online), n)

    def _sparse_round(self, rng, data, n):
        """O(cohort) draw for huge populations: candidates drawn uniformly
        (with replacement, deduplicated — collisions are vanishing at
        n << num_clients), each kept with prob ``p``. The accepted prefix
        is a uniform sample of the Bernoulli(p) online set; work and
        memory scale with the cohort, never the population."""
        N = data.num_clients
        accepted = np.empty(0, np.int64)
        for _ in range(64):
            if len(accepted) >= n:
                break
            need = n - len(accepted)
            m = min(max(int(np.ceil(need / self.prob)) * 2, 32), 1 << 16)
            cand = rng.integers(0, N, size=m)
            keep = cand[rng.random(m) < self.prob]
            accepted = _stable_unique(np.concatenate([accepted, keep]))
        if len(accepted) >= n:
            ids = accepted[:n]
            return ids, _size_weights(data, ids)
        # pathological prob: pad with distinct offline ids at weight 0
        # (same shortfall policy as the dense branch)
        k = len(accepted)
        fill = _draw_distinct(rng, N, n - k, exclude=accepted)
        ids = np.concatenate([accepted, fill])
        if k == 0:                        # all-offline guard, as dense
            return ids, _size_weights(data, ids)
        return ids, self._shortfall_weights(data, ids, k, n)

    @staticmethod
    def _shortfall_weights(data, ids, n_online, n):
        w = np.array([len(data.client_y[c]) for c in ids[:n_online]],
                     np.float64)
        if w.sum() <= 0:                  # online but data-less: uniform
            w = np.ones_like(w)
        weights = np.zeros(n, np.float32)
        weights[:n_online] = (w / w.sum()).astype(np.float32)
        return weights

    def sample(self, rng, data, n, round_idx=None):
        return self.round(rng, data, n, round_idx)[0]


def _draw_distinct(rng: np.random.Generator, N: int, k: int,
                   exclude: np.ndarray) -> np.ndarray:
    """k distinct ids from [0, N) avoiding ``exclude`` — O(k) for k << N."""
    out = np.empty(0, np.int64)
    for _ in range(64):
        if len(out) >= k:
            break
        cand = rng.integers(0, N, size=max(2 * (k - len(out)), 16))
        cand = cand[~np.isin(cand, exclude)]
        out = _stable_unique(np.concatenate([out, cand]))
    if len(out) < k:                      # tiny N fallback: exact set diff
        rest = np.setdiff1d(np.arange(N), np.concatenate([exclude, out]),
                            assume_unique=False)
        out = np.concatenate([out, rest])
    return out[:k]


class PopulationSampler(ClientSampler):
    """Population-scale diurnal availability over a virtual id space
    (DESIGN.md §11).

    Each client id's timezone phase is ``splitmix64(id) / 2^64`` — a pure
    hash, so the 10^6+ population stores NO per-client state. At absolute
    round r the time-of-day is ``(r % day_rounds) / day_rounds`` and a
    client's availability follows the cosine day curve

        p_c(r) = base + (peak - base) * (1 + cos(2π(tod - phase_c))) / 2

    — clients whose phase matches the current time-of-day are at ``peak``,
    the antipodal timezone at ``base``. The cohort is drawn by O(cohort)
    rejection: uniform candidate ids accepted with prob ``p_c(r)/peak``,
    i.e. participation ∝ availability. Weights are dataset-size weights
    over the accepted cohort (shortfall pads at weight 0, as
    ``availability``)."""

    name = "population"
    needs_weighted_aggregation = True

    def __init__(self, population: int = 0, peak: float = 0.9,
                 base: float = 0.05, day_rounds: int = 24):
        if population < 0:
            raise ValueError(f"population must be >= 0: {population}")
        if not 0.0 < peak <= 1.0:
            raise ValueError(f"peak availability must be in (0, 1]: {peak}")
        if not 0.0 < base <= peak:
            raise ValueError(f"base availability must be in (0, peak]: "
                             f"{base}")
        if day_rounds < 1:
            raise ValueError(f"day_rounds must be >= 1: {day_rounds}")
        self.population = int(population)
        self.peak = float(peak)
        self.base = float(base)
        self.day_rounds = int(day_rounds)

    def availability(self, ids: np.ndarray, round_idx: int) -> np.ndarray:
        """Per-id availability at absolute round ``round_idx`` — pure
        function of (id, round), no stored state."""
        tod = (int(round_idx) % self.day_rounds) / self.day_rounds
        phase = _hash_unit(np.asarray(ids))
        day = 0.5 * (1.0 + np.cos(2.0 * np.pi * (tod - phase)))
        return self.base + (self.peak - self.base) * day

    def round(self, rng, data, n, round_idx=None):
        N = self.population or data.num_clients
        n = min(n, N)
        r = 1 if round_idx is None else int(round_idx)
        accepted = np.empty(0, np.int64)
        for _ in range(64):
            if len(accepted) >= n:
                break
            need = n - len(accepted)
            # mean acceptance is >= base/peak; oversample against it
            m = min(max(int(np.ceil(need * self.peak / self.base)) * 2, 32),
                    1 << 16)
            cand = rng.integers(0, N, size=m)
            keep = cand[rng.random(m) * self.peak
                        < self.availability(cand, r)]
            accepted = _stable_unique(np.concatenate([accepted, keep]))
        if len(accepted) >= n:
            ids = accepted[:n]
            return ids, _size_weights(data, ids)
        k = len(accepted)
        fill = _draw_distinct(rng, N, n - k, exclude=accepted)
        ids = np.concatenate([accepted, fill])
        if k == 0:
            return ids, _size_weights(data, ids)
        return ids, AvailabilitySampler._shortfall_weights(data, ids, k, n)

    def sample(self, rng, data, n, round_idx=None):
        return self.round(rng, data, n, round_idx)[0]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

register_sampler("uniform", lambda *, fed=None, **kw: UniformSampler())
register_sampler("weighted", lambda *, fed=None, **kw: WeightedSampler())
register_sampler(
    "fixed_cohort",
    lambda *, fed=None, **kw: FixedCohortSampler(
        cohort=getattr(fed, "cohort", None)))
register_sampler(
    "availability",
    lambda *, fed=None, **kw: AvailabilitySampler(
        prob=getattr(fed, "availability", 0.9)))
register_sampler(
    "population",
    lambda *, fed=None, **kw: PopulationSampler(
        population=getattr(fed, "population", 0),
        peak=getattr(fed, "availability", 0.9),
        base=getattr(fed, "base_availability", 0.05),
        day_rounds=getattr(fed, "day_rounds", 24)))

SAMPLERS = ("uniform", "weighted", "fixed_cohort", "availability",
            "population")


def get_sampler(name, *, fed=None, **kw) -> ClientSampler:
    """Resolve a sampler by name (a ``ClientSampler`` instance passes
    through). ``fed`` supplies per-sampler configuration (cohort,
    availability)."""
    if isinstance(name, ClientSampler):
        return name
    return SAMPLER_REGISTRY.get(name)(fed=fed, **kw)


def make_sampler(fed) -> ClientSampler:
    """The trainer's entry point: build the FedConfig's sampler."""
    return get_sampler(getattr(fed, "sampler", "uniform") or "uniform",
                       fed=fed)
