"""ClientSampler — who participates in each federated round (DESIGN.md §9.3).

Algorithm 1 line 3 ("sample C_r uniformly") was hard-coded in the data
pipeline; partial-participation regimes whose convergence depends on the
sampling scheme (Li et al., *On the Convergence of FedAvg on Non-IID
Data*) could not be expressed. A ``ClientSampler`` owns both decisions a
round opens with: *which* clients run, and with what aggregation *weights*.

Samplers (registered in ``repro.api.registries``):

  * ``uniform``      — without-replacement uniform draw. Consumes EXACTLY
    the rng stream of the historical ``pipeline.sample_clients`` call, so
    the default configuration is bitwise-identical to every prior PR.
  * ``weighted``     — draw probability proportional to client dataset
    size (importance sampling for heavy-tailed client populations).
  * ``fixed_cohort`` — the same cohort every round, in a stable order:
    cross-silo FL, where clients are stateful organisations. Declares
    ``stateful_cohort``, which switches transport error feedback from the
    server-aggregate residual to per-client residual slots
    (``Transport.with_ef_slots``; slot j is always cohort[j]).
  * ``availability`` — per-round participation mask: each client is online
    with probability ``p`` this round; the cohort is drawn from the online
    set. If fewer than ``n`` clients are online the cohort is padded with
    offline clients at aggregation weight 0 (shape stability for the jitted
    round; zero weight = they contribute nothing to *linear* aggregators —
    combining availability shortfall with median/trimmed_mean is rejected
    by spec validation).

The sampler runs on the host, inside the bucket builder (possibly on the
prefetch thread — requests are FIFO on one rng, so results depend only on
(rng state, submission order), never on timing).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.api.registries import SAMPLER_REGISTRY, register_sampler
from repro.data.pipeline import client_weights as _size_weights
from repro.data.synthetic import FederatedData


class ClientSampler:
    """Protocol. ``round(rng, data, n, round_idx)`` -> (ids (n,), weights
    (n,) f32 summing to 1). ``round_idx`` is the absolute 1-based round
    index (schedule-stable across checkpoint resume); samplers that do not
    depend on it must ignore it."""

    name: str = "base"
    #: True => the cohort is fixed for the whole run and slot j always maps
    #: to the same client — per-client transport error feedback is sound.
    stateful_cohort: bool = False
    #: True => the sampler communicates participation through the weight
    #: vector (zero-weight slots), so aggregation must respect weights —
    #: the trainer rejects weight-ignoring (robust) aggregators.
    needs_weighted_aggregation: bool = False

    def sample(self, rng: np.random.Generator, data: FederatedData, n: int,
               round_idx: Optional[int] = None) -> np.ndarray:
        raise NotImplementedError

    def weights(self, data: FederatedData, ids: np.ndarray) -> np.ndarray:
        return _size_weights(data, ids)

    def round(self, rng: np.random.Generator, data: FederatedData, n: int,
              round_idx: Optional[int] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        ids = self.sample(rng, data, n, round_idx)
        return ids, self.weights(data, ids)


class UniformSampler(ClientSampler):
    """Uniform without replacement — draw-for-draw the historical stream
    (delegates to the historical draw itself, so the bitwise contract is
    true by construction)."""

    name = "uniform"

    def sample(self, rng, data, n, round_idx=None):
        from repro.data.pipeline import sample_clients
        return sample_clients(rng, data, n)


class WeightedSampler(ClientSampler):
    """Inclusion probability proportional to client dataset size."""

    name = "weighted"

    def sample(self, rng, data, n, round_idx=None):
        sizes = np.array([len(y) for y in data.client_y], dtype=np.float64)
        return rng.choice(data.num_clients, size=min(n, data.num_clients),
                          replace=False, p=sizes / sizes.sum())


class FixedCohortSampler(ClientSampler):
    """The same clients, in the same slot order, every round (cross-silo).

    ``cohort=None`` defaults to clients ``0..n-1``. Consumes no rng, so two
    runs differing only in cohort membership share their batch-sampling
    stream per slot."""

    name = "fixed_cohort"
    stateful_cohort = True

    def __init__(self, cohort: Optional[Sequence[int]] = None):
        self.cohort = None if cohort is None else tuple(int(c) for c in cohort)

    def sample(self, rng, data, n, round_idx=None):
        cohort = self.cohort if self.cohort is not None else tuple(range(n))
        if len(cohort) != n:
            raise ValueError(f"fixed cohort has {len(cohort)} clients, "
                             f"round needs {n}")
        bad = [c for c in cohort if not 0 <= c < data.num_clients]
        if bad:
            raise ValueError(f"cohort ids {bad} out of range "
                             f"[0, {data.num_clients})")
        return np.asarray(cohort, dtype=np.int64)


class AvailabilitySampler(ClientSampler):
    """Bernoulli(p) per-round participation mask (cross-device churn).

    Shortfall policy: when fewer than ``n`` clients are online, offline
    clients pad the cohort at weight 0 so the jitted round keeps its shape.

    Degenerate-round guard: a round with NOBODY online re-draws as a plain
    uniform round (documented deviation — the jitted schedule cannot skip
    a round in this simulation). Padding the whole cohort at weight 0
    instead would make the weighted mean a 0/0 and poison the params with
    NaN, which is why the guard also covers the weight normalisation in
    the shortfall branch (all-empty online datasets fall back to uniform
    weights over the online set). Regression-tested at ``prob≈0`` in
    ``tests/test_sampling.py``."""

    name = "availability"
    needs_weighted_aggregation = True   # shortfall padding rides zero weights

    def __init__(self, prob: float = 0.9):
        if not 0.0 < prob <= 1.0:
            raise ValueError(f"availability prob must be in (0, 1]: {prob}")
        self.prob = float(prob)

    def round(self, rng, data, n, round_idx=None):
        n = min(n, data.num_clients)
        online = np.flatnonzero(rng.random(data.num_clients) < self.prob)
        if len(online) == 0:              # all-offline: re-draw uniformly
            ids = rng.choice(data.num_clients, size=n, replace=False)
            return ids, _size_weights(data, ids)
        if len(online) >= n:
            ids = rng.choice(online, size=n, replace=False)
            return ids, _size_weights(data, ids)
        offline = np.setdiff1d(np.arange(data.num_clients), online,
                               assume_unique=True)
        fill = rng.choice(offline, size=n - len(online), replace=False)
        ids = np.concatenate([online, fill])
        w = np.array([len(data.client_y[c]) for c in online], np.float64)
        if w.sum() <= 0:                  # online but data-less: uniform
            w = np.ones_like(w)
        weights = np.zeros(n, np.float32)
        weights[:len(online)] = (w / w.sum()).astype(np.float32)
        return ids, weights

    def sample(self, rng, data, n, round_idx=None):
        return self.round(rng, data, n, round_idx)[0]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

register_sampler("uniform", lambda *, fed=None, **kw: UniformSampler())
register_sampler("weighted", lambda *, fed=None, **kw: WeightedSampler())
register_sampler(
    "fixed_cohort",
    lambda *, fed=None, **kw: FixedCohortSampler(
        cohort=getattr(fed, "cohort", None)))
register_sampler(
    "availability",
    lambda *, fed=None, **kw: AvailabilitySampler(
        prob=getattr(fed, "availability", 0.9)))

SAMPLERS = ("uniform", "weighted", "fixed_cohort", "availability")


def get_sampler(name, *, fed=None, **kw) -> ClientSampler:
    """Resolve a sampler by name (a ``ClientSampler`` instance passes
    through). ``fed`` supplies per-sampler configuration (cohort,
    availability)."""
    if isinstance(name, ClientSampler):
        return name
    return SAMPLER_REGISTRY.get(name)(fed=fed, **kw)


def make_sampler(fed) -> ClientSampler:
    """The trainer's entry point: build the FedConfig's sampler."""
    return get_sampler(getattr(fed, "sampler", "uniform") or "uniform",
                       fed=fed)
