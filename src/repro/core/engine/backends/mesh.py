"""MeshBackend — GSPMD mesh execution of the round's client fan-out.

Absorbs the two mesh strategies that previously lived (scheduler-less and
aggregator-less) in ``repro.distributed.strategies``:

* ``parallel`` (cross-device FL): the client axis is a ``vmap`` dim sharded
  over the mesh ``data`` (x ``pod``) axes via ``spmd_axis_name``; the
  aggregation contracts the client axis — GSPMD turns it into the
  aggregation all-reduce, or, with ``aggregator="kernel"``, the explicit
  client-sharded Pallas reduction (local block-reduce + all-reduce of the
  per-shard partials, ``kernels.fedavg_reduce_sharded``).

* ``sequential`` (cross-silo FL, 100B+ archs): one fully-sharded parameter
  set; ``groups`` client groups run as a vmap (hierarchical FL, one group
  per pod), clients within a group as a ``lax.scan`` using the whole mesh.
  Linear aggregators (mean/kernel) stream as a running weighted sum in
  ``acc_dtype`` — the (N, ...) client stack is never materialised; robust
  aggregators (median/trimmed_mean) need the coordinate-wise client
  distribution, so the scan stacks per-client params (documented memory
  trade: N x params, same as the parallel path).

With ``mesh=None`` the backend builds the same round cores (sharding
annotations only) for abstract lowering — ``launch.dryrun`` traces through
this path; placement hooks then degrade to plain transfers.

On a 1x1 host mesh every path is numerically equivalent to ``LocalBackend``
(tests/test_backends.py), which is what makes the engine's K-bucketed scan,
server optimizers and robust aggregators safe to drive the production path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.engine.aggregators import get_aggregator
from repro.core.engine.backends.base import (ExecutionBackend,
                                             LINEAR_AGGREGATORS, LossFn,
                                             axes_size as _axes_size)
from repro.core.engine.backends.local import (encode_broadcast,
                                              make_parallel_round_core,
                                              make_parallel_slab_cores)
from repro.core.engine.client import client_update

PyTree = Any


def carve_submeshes(mesh, n: int):
    """Split ``mesh`` into up to ``n`` disjoint sub-meshes for fleet packing
    (DESIGN.md §12): the device grid is cut along its largest axis into
    ``g`` contiguous slices, where ``g`` is the largest divisor of that
    axis's size with ``g <= n`` — every slice keeps the full axis-name
    structure, so per-point MeshBackends reuse the parent's sharding rules
    unchanged. A 1-device (or un-splittable) mesh returns ``[mesh]``; the
    caller round-robins points over whatever came back."""
    devices = mesh.devices
    shape = devices.shape
    axis = max(range(len(shape)), key=lambda i: shape[i])
    size = shape[axis]
    g = max((d for d in range(1, min(n, size) + 1) if size % d == 0),
            default=1)
    if g <= 1:
        return [mesh]
    step = size // g
    out = []
    for i in range(g):
        idx = [slice(None)] * len(shape)
        idx[axis] = slice(i * step, (i + 1) * step)
        out.append(type(mesh)(devices[tuple(idx)], mesh.axis_names))
    return out


class MeshBackend(ExecutionBackend):
    name = "mesh"

    def __init__(self, mesh=None, *, strategy: str = "parallel",
                 client_axes: Optional[Sequence[str]] = None,
                 groups: int = 1, param_specs: Optional[PyTree] = None,
                 acc_dtype=jnp.float32, reduce: str = "flat"):
        """``client_axes``: mesh axes the client dim shards over (defaults
        to ``("pod", "data")``/``("data",)`` from the mesh's axis names);
        ``param_specs``: PartitionSpec tree pinning params (sequential FSDP
        keeps the delta accumulator on the params' 2d sharding);
        ``acc_dtype``: sequential streaming-accumulator dtype — f32 default
        preserves LocalBackend numerics, bf16 halves the scan carry;
        ``reduce``: ``"flat"`` for one psum over all client axes, or
        ``"grouped"`` for the hierarchical two-tier reduce (DESIGN.md §11:
        psum within the innermost client axis — edge aggregation local to a
        pod — then across the remaining axes, innermost-out)."""
        if strategy not in ("parallel", "sequential"):
            raise ValueError(f"unknown strategy {strategy!r}")
        if reduce not in ("flat", "grouped"):
            raise ValueError(f"unknown reduce {reduce!r}")
        self.mesh = mesh
        self.strategy = strategy
        if client_axes is None and mesh is not None:
            client_axes = ("pod", "data") if "pod" in mesh.axis_names \
                else ("data",)
        self.client_axes = tuple(client_axes) if client_axes else None
        self.groups = max(int(groups), 1)
        self.param_specs = param_specs
        self.acc_dtype = acc_dtype
        self.reduce = reduce
        # innermost axis first: ("pod", "data") -> (("data",), ("pod",))
        self.reduce_tiers = (
            tuple((a,) for a in reversed(self.client_axes))
            if reduce == "grouped" and self.client_axes else None)

    # ------------------------------------------------------------------
    # round core
    # ------------------------------------------------------------------
    def make_round_core(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        trim_fraction: float = 0.1, server=None,
                        server_lr: float = 1.0, transport=None,
                        downlink=None):
        if transport is not None and self.mesh is not None:
            # bound copy: reduce() routes through the client-sharded
            # decompress-reduce kernel (delta_codec, DESIGN.md §8)
            transport = transport.with_mesh(self.mesh, self.client_axes,
                                            self.reduce_tiers)
        if self.strategy == "parallel":
            agg = self._resolve_aggregator(aggregator, trim_fraction)
            return make_parallel_round_core(
                loss_fn, agg, server, server_lr,
                client_spmd_axes=self.client_axes, transport=transport,
                downlink=downlink,
                constrain=(self.constrain_update if downlink is not None
                           else None))
        if transport is not None and transport.name == "none":
            # identity codec: keep the legacy sequential core (streaming
            # linear / stacking robust aggregators) and thread the empty
            # transport state through unchanged
            inner = self._make_sequential_core(loss_fn, aggregator,
                                               trim_fraction, server,
                                               server_lr)

            def identity_core(params, batches, weights, eta, server_state,
                              t_state):
                p, f, l, s = inner(params, batches, weights, eta,
                                   server_state)
                return p, f, l, s, t_state

            core = identity_core
        else:
            core = self._make_sequential_core(loss_fn, aggregator,
                                              trim_fraction, server,
                                              server_lr, transport)
        if downlink is None:
            return core
        return self._wrap_sequential_downlink(core, transport, downlink)

    def make_slab_cores(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        server=None, server_lr: float = 1.0, transport=None):
        if self.strategy != "parallel":
            raise ValueError(
                "cohort_chunk requires the parallel strategy: the grouped "
                "sequential scan already streams clients without a slab "
                "decomposition")
        if transport is not None and self.mesh is not None:
            transport = transport.with_mesh(self.mesh, self.client_axes,
                                            self.reduce_tiers)
        agg = self._resolve_aggregator(aggregator, 0.1)
        return make_parallel_slab_cores(loss_fn, agg, server, server_lr,
                                        client_spmd_axes=self.client_axes,
                                        transport=transport)

    def fleet_slices(self, n: int):
        """One MeshBackend per packed sweep point, on disjoint sub-meshes
        carved from this backend's mesh (cycled when the mesh splits into
        fewer slices than points). Strategy/groups/acc_dtype/reduce and the
        param spec tree carry over; ``client_axes`` re-derive from the
        slice's axis names, which ``carve_submeshes`` preserves."""
        if self.mesh is None:
            return [self] * n
        meshes = carve_submeshes(self.mesh, n)
        return [MeshBackend(meshes[i % len(meshes)],
                            strategy=self.strategy,
                            client_axes=self.client_axes,
                            groups=self.groups,
                            param_specs=self.param_specs,
                            acc_dtype=self.acc_dtype,
                            reduce=self.reduce)
                for i in range(n)]

    def _wrap_sequential_downlink(self, core, transport, downlink):
        """Downlink around a sequential core (DESIGN.md §10): the scan
        reuses ONE reconstruction per round — decode happens at the core
        top, not per client-scan step — so the per-client work is
        unchanged while ref/payload stay the only broadcast-sized state."""
        constrain = self.constrain_update

        if transport is None:
            def d_core(params, batches, weights, eta, server_state,
                       d_state):
                _, _, recon, d_state, level = encode_broadcast(
                    downlink, params, d_state)
                recon = constrain(recon)
                p, f, l, s = core(recon, batches, weights, eta,
                                  server_state)
                return p, f, l, s, d_state, level

            return d_core

        def td_core(params, batches, weights, eta, server_state, extra):
            t_state, d_state = extra
            _, _, recon, d_state, level = encode_broadcast(
                downlink, params, d_state)
            recon = constrain(recon)
            p, f, l, s, t = core(recon, batches, weights, eta,
                                 server_state, t_state)
            return p, f, l, s, (t, d_state), level

        return td_core

    def _resolve_aggregator(self, name: str, trim_fraction: float):
        if name == "kernel" and self.mesh is not None:
            from repro.kernels import ops as kops
            mesh, axes = self.mesh, self.client_axes
            tiers = self.reduce_tiers
            size = _axes_size(mesh, axes)
            plain = get_aggregator("kernel")

            def sharded_kernel(client_params, weights):
                n = weights.shape[0]
                if n % size != 0:                # static at trace time
                    return plain(client_params, weights)
                return kops.fedavg_reduce_tree_sharded(
                    client_params, weights, mesh=mesh, client_axes=axes,
                    reduce_tiers=tiers)

            return sharded_kernel
        return get_aggregator(name, trim_fraction=trim_fraction)

    def _make_sequential_core(self, loss_fn, aggregator, trim_fraction,
                              server, server_lr, transport=None):
        if transport is not None:
            return self._make_sequential_transport_core(loss_fn, server,
                                                        server_lr, transport)
        stream = aggregator in LINEAR_AGGREGATORS
        agg = None if stream else get_aggregator(aggregator,
                                                 trim_fraction=trim_fraction)
        groups, acc_dtype = self.groups, self.acc_dtype
        param_specs, axes = self.param_specs, self.client_axes

        def constrain(tree):
            # keep the accumulator/client params on the params' sharding —
            # without this GSPMD replicates full weights inside the scan
            if param_specs is None:
                return tree
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, self._spec_sharding(s)),
                tree, param_specs)

        def round_core(params, batches, weights, eta, server_state):
            n = weights.shape[0]
            if n % groups:
                raise ValueError(f"{n} clients not divisible into "
                                 f"{groups} groups")
            ng = n // groups
            gb = jax.tree.map(
                lambda x: x.reshape((groups, ng) + x.shape[1:]), batches)
            gw = weights.reshape(groups, ng)
            if stream:
                def per_group(group_batches, group_w):
                    def client(acc, inp):
                        cb, w = inp
                        res = client_update(loss_fn, params, cb, eta)
                        cp = constrain(res.params)
                        acc = constrain(jax.tree.map(
                            lambda a, c: (a + w.astype(acc_dtype)
                                          * c.astype(acc_dtype)
                                          ).astype(acc_dtype), acc, cp))
                        return acc, (res.first_loss, res.last_loss)

                    zeros = constrain(jax.tree.map(
                        lambda p: jnp.zeros(p.shape, acc_dtype), params))
                    return jax.lax.scan(client, zeros,
                                        (group_batches, group_w))

                accs, (firsts, lasts) = jax.vmap(
                    per_group, spmd_axis_name=axes)(gb, gw)
                aggregate = jax.tree.map(
                    lambda p, a: jnp.sum(a, axis=0).astype(p.dtype),
                    params, accs)
            else:
                def per_group(group_batches):
                    def client(carry, cb):
                        res = client_update(loss_fn, params, cb, eta)
                        return carry, (constrain(res.params),
                                       res.first_loss, res.last_loss)

                    _, ys = jax.lax.scan(client, 0, group_batches)
                    return ys

                cps, firsts, lasts = jax.vmap(
                    per_group, spmd_axis_name=axes)(gb)
                stack = jax.tree.map(
                    lambda x: x.reshape((n,) + x.shape[2:]), cps)
                aggregate = agg(stack, weights)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return (new_params, firsts.reshape(n), lasts.reshape(n),
                    server_state)

        return round_core

    def _make_sequential_transport_core(self, loss_fn, server, server_lr,
                                        transport):
        """Streaming compressed sequential core (DESIGN.md §8): each client
        in the scan encodes its (error-corrected) delta and the decoded
        payload folds into a running f32 weighted sum — neither the (N, ...)
        client stack nor the decoded per-client deltas are ever stacked.
        Error feedback additionally streams the true weighted delta sum, so
        the residual update matches the parallel path's exactly (modulo sum
        re-association, the documented sequential-parity regime)."""
        groups = self.groups
        param_specs, axes = self.param_specs, self.client_axes
        ef = transport.error_feedback
        per_client_ef = ef and bool(getattr(transport, "ef_slots", None))

        def constrain(tree):
            if param_specs is None:
                return tree
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, self._spec_sharding(s)),
                tree, param_specs)

        def round_core(params, batches, weights, eta, server_state, t_state):
            n = weights.shape[0]
            if n % groups:
                raise ValueError(f"{n} clients not divisible into "
                                 f"{groups} groups")
            ng = n // groups
            gb = jax.tree.map(
                lambda x: x.reshape((groups, ng) + x.shape[1:]), batches)
            gw = weights.reshape(groups, ng)
            # per-client EF (fixed cohorts): residual slot i rides the scan
            # as a per-client xs/ys pair — client i reads ITS residual and
            # writes ITS compression error; no cross-client mixing
            gt = (jax.tree.map(
                lambda x: x.reshape((groups, ng) + x.shape[1:]), t_state)
                if per_client_ef else gw)   # gw = cheap dummy xs slot

            def per_group(group_batches, group_w, group_t):
                def client(carry, inp):
                    hat_acc, true_acc = carry
                    cb, w, t_slot = inp
                    res = client_update(loss_fn, params, cb, eta)
                    delta = constrain(jax.tree.map(
                        lambda c, p: c.astype(jnp.float32)
                        - p.astype(jnp.float32), res.params, params))
                    if ef:
                        delta = constrain(jax.tree.map(
                            jnp.add, delta,
                            t_slot if per_client_ef else t_state))
                    dec = transport.decode(transport.encode(delta),
                                           like=params)
                    w32 = w.astype(jnp.float32)
                    hat_acc = constrain(jax.tree.map(
                        lambda a, d: a + w32 * d, hat_acc, dec))
                    if per_client_ef:
                        new_slot = jax.tree.map(jnp.subtract, delta, dec)
                        return ((hat_acc, true_acc),
                                (new_slot, res.first_loss, res.last_loss))
                    if ef:
                        true_acc = constrain(jax.tree.map(
                            lambda a, d: a + w32 * d, true_acc, delta))
                    return ((hat_acc, true_acc),
                            ((), res.first_loss, res.last_loss))

                zeros = constrain(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params))
                zeros_t = zeros if (ef and not per_client_ef) else ()
                return jax.lax.scan(client, (zeros, zeros_t),
                                    (group_batches, group_w, group_t))

            (hat_g, true_g), (slots_g, firsts, lasts) = jax.vmap(
                per_group, spmd_axis_name=axes)(gb, gw, gt)
            hat = jax.tree.map(lambda a: jnp.sum(a, axis=0), hat_g)
            if per_client_ef:
                new_t = jax.tree.map(
                    lambda x: x.reshape((n,) + x.shape[2:]), slots_g)
            elif ef:
                true = jax.tree.map(lambda a: jnp.sum(a, axis=0), true_g)
                new_t = jax.tree.map(jnp.subtract, true, hat)
            else:
                new_t = t_state
            aggregate = jax.tree.map(
                lambda p, h: (p.astype(jnp.float32) + h).astype(p.dtype),
                params, hat)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return (new_params, firsts.reshape(n), lasts.reshape(n),
                    server_state, new_t)

        return round_core

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _spec_sharding(self, s):
        """Resolve a param-spec entry for ``with_sharding_constraint``:
        concrete mesh -> NamedSharding (no mesh context needed); abstract
        lowering (mesh=None) or an already-built Sharding pass through."""
        if self.mesh is None or isinstance(s, jax.sharding.Sharding):
            return s
        return self._named(s)

    def place_params(self, params: PyTree) -> PyTree:
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, params)
        if self.param_specs is not None:
            return jax.tree.map(
                lambda x, s: jax.device_put(x, self._spec_sharding(s)),
                params, self.param_specs)
        rep = self._named(P())
        return jax.tree.map(lambda x: jax.device_put(x, rep), params)

    def _batch_spec(self, shape: Tuple[int, ...]) -> P:
        """Bucket leaves (B, N, K, b, ...): client dim sharded (parallel) or
        the per-client batch dim data-sharded (sequential)."""
        if self.strategy == "parallel":
            if self.client_axes and \
                    shape[1] % _axes_size(self.mesh, self.client_axes) == 0:
                return P(None, self.client_axes)
            return P()
        if len(shape) >= 4 and \
                shape[3] % _axes_size(self.mesh, ("data",)) == 0 \
                and "data" in self.mesh.axis_names:
            return P(None, None, None, "data")
        return P()

    def place_batches(self, batches: Dict[str, Any]) -> Dict[str, Any]:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batches.items()}
        return {k: jax.device_put(jnp.asarray(v),
                                  self._named(self._batch_spec(v.shape)))
                for k, v in batches.items()}

    def place_slab(self, sb):
        """Slab leaves (C, K, b, ...) carry the client dim FIRST (no bucket
        dim): shard dim 0 over the client axes when C divides the shard
        count (parallel strategy), replicate otherwise — same policy as
        ``_batch_spec`` shifted one dim left. Weights (C,) ride the same
        spec so the per-shard reduce sees matching slices."""
        if self.mesh is None:
            return super().place_slab(sb)
        c = int(sb.weights.shape[0])
        spec = P()
        if self.strategy == "parallel" and self.client_axes and \
                c % _axes_size(self.mesh, self.client_axes) == 0:
            spec = P(self.client_axes)
        sh = self._named(spec)
        return dataclasses.replace(
            sb,
            batches={k: jax.device_put(jnp.asarray(v), sh)
                     for k, v in sb.batches.items()},
            weights=jax.device_put(jnp.asarray(sb.weights, jnp.float32), sh))

    def place_transport_state(self, state, per_client: bool = False):
        """Aggregate-level EF state is params-shaped and rides the params
        placement; per-client EF state (leading cohort axis, DESIGN.md
        §9.3) must NOT take ``param_specs`` — a leading-dims PartitionSpec
        would shard the cohort axis with the spec meant for the param's
        first dim — instead the leading cohort axis itself shards over the
        client axes (parallel strategy, divisible cohort; DESIGN.md §11),
        so the EF slab's memory scales 1/shards like the client stack.
        Sequential scans carry EF through xs/ys, so it stays replicated
        there (and on indivisible cohorts)."""
        if not jax.tree.leaves(state):
            return state
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, state)
        if per_client:
            spec = self._cohort_spec(state)
            sh = self._named(spec)
            return jax.tree.map(lambda x: jax.device_put(x, sh), state)
        return self.place_params(state)

    def _cohort_spec(self, state) -> P:
        """PartitionSpec for per-client (leading cohort axis) state: shard
        the cohort axis when the parallel vmap will consume it sharded."""
        if self.strategy != "parallel" or not self.client_axes:
            return P()
        size = _axes_size(self.mesh, self.client_axes)
        leaves = jax.tree.leaves(state)
        if any(leaf.shape[0] % size != 0 for leaf in leaves):
            return P()
        return P(self.client_axes)

    def bind_downlink(self, codec):
        """Bound copy: ``decode_apply`` routes through the client-sharded
        decode-apply kernel — the flat parameter vector is split over the
        mesh client axes and each shard reconstructs its slice
        (DESIGN.md §8.6)."""
        if codec is None or self.mesh is None:
            return codec
        return codec.with_mesh(self.mesh, self.client_axes)

    def place_weights(self, weights) -> jnp.ndarray:
        w = jnp.asarray(weights, jnp.float32)
        if self.mesh is None:
            return w
        spec = P()
        if self.strategy == "parallel" and self.client_axes and \
                w.shape[-1] % _axes_size(self.mesh, self.client_axes) == 0:
            spec = P(*((None,) * (w.ndim - 1)), self.client_axes)
        return jax.device_put(w, self._named(spec))

    # ------------------------------------------------------------------
    # output sharding pinning (DESIGN.md §7.3)
    # ------------------------------------------------------------------
    def constrain_update(self, tree: PyTree) -> PyTree:
        """Pin params-like executable outputs to the placement sharding
        (param_specs, or replicated): the next bucket's ``place_params``
        then sees an already-canonical sharding and skips the per-bucket
        ``device_put`` resharding (the PR-2 ROADMAP item)."""
        if self.mesh is None or not jax.tree.leaves(tree):
            return tree
        if self.param_specs is None:
            rep = self._named(P())
            return jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(x, rep), tree)
        try:
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, self._spec_sharding(s)), tree, self.param_specs)
        except ValueError:
            # tree is not params-shaped (exotic server/transport state) —
            # leave its sharding to GSPMD
            return tree

    def constrain_transport_update(self, tree: PyTree,
                                   per_client: bool = False) -> PyTree:
        if not per_client:
            return self.constrain_update(tree)
        if self.mesh is None or not jax.tree.leaves(tree):
            return tree
        sh = self._named(self._cohort_spec(tree))   # cohort-axis sharding
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, sh), tree)

