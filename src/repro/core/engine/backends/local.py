"""LocalBackend — single-device execution (the PR-1 engine's geometry).

The round's N clients run as a plain ``jax.vmap`` over the client axis;
aggregation is the configured Aggregator verbatim; placement is a plain
transfer. This is the degenerate point of the backend protocol: everything
``MeshBackend`` does collapses to this on a 1x1 mesh, which is exactly what
the parity tests assert (tests/test_backends.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.backends.base import ExecutionBackend, LossFn
from repro.core.engine.client import make_client_update


def encode_broadcast(downlink, params, d_state):
    """Uniform downlink-core entry point: every downlink round core emits
    ``(ref, payload, recon, new_state, level)`` — codecs without a
    per-round level (everything but ``adaptive``) get the sentinel -1,
    which the trainer reads as "charge the configured ratio"."""
    out = downlink.encode_broadcast(params, d_state)
    if len(out) == 5:
        return out
    ref, payload, recon, new_state = out
    return ref, payload, recon, new_state, jnp.int32(-1)


def make_parallel_round_core(loss_fn: LossFn, aggregator: Aggregator,
                             server, server_lr: float, *,
                             client_spmd_axes: Optional[Sequence[str]] = None,
                             transport=None, downlink=None, constrain=None):
    """The vmap-over-clients round core shared by Local and Mesh-parallel.

    ``client_spmd_axes``: mesh axes the vmapped client dim is sharded over
    (``spmd_axis_name``); None on a single device.

    round_core(params, batches{(N,K,b,...)}, weights(N,), eta, server_state)
    -> (new_params, first_losses (N,), last_losses (N,), server_state).

    With ``transport`` (DESIGN.md §8) the clients' stacked params go through
    the codec's delta pipeline (encode -> fused decompress-reduce) instead
    of the aggregator, and the core threads the transport state:
    round_core(..., server_state, t_state) -> (..., server_state, t_state).

    With ``downlink`` (DESIGN.md §10) the broadcast is *fused into the
    client forward*: the core's extra carry slot is the downlink state (or
    an ``(uplink, downlink)`` pair), the server encodes once, and each
    vmapped client reconstructs ``ref + dec(payload)`` lazily inside its
    own first step (``client.reconstruct``) — the decoded f32 tree is
    never a separate engine-materialised round input. The server-side
    reconstruction (aggregate target, next reference) is the identical
    elementwise program, so XLA CSEs the two decodes under jit. Downlink
    cores additionally return the per-round adaptive level scalar.
    ``constrain`` pins the server-side reconstruction to the backend's
    param sharding (mesh); None on a single device.
    """
    if downlink is None:
        client = make_client_update(loss_fn)

        if transport is None:
            def round_core(params, batches, weights, eta, server_state):
                client_params, first_losses, last_losses = jax.vmap(
                    client, in_axes=(None, 0, None),
                    spmd_axis_name=client_spmd_axes)(params, batches, eta)
                aggregate = aggregator(client_params, weights)
                new_params, server_state = server.step(params, aggregate,
                                                       server_state,
                                                       server_lr)
                return new_params, first_losses, last_losses, server_state

            return round_core

        def round_core(params, batches, weights, eta, server_state, t_state):
            client_params, first_losses, last_losses = jax.vmap(
                client, in_axes=(None, 0, None),
                spmd_axis_name=client_spmd_axes)(params, batches, eta)
            aggregate, t_state = transport.aggregate(
                aggregator, params, client_params, weights, t_state)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return (new_params, first_losses, last_losses, server_state,
                    t_state)

        return round_core

    # fused downlink path: the vmapped "params" argument is the broadcast
    # bundle (ref, payload), unbatched (in_axes=None) so the decode traces
    # once and is shared across clients
    fused = make_client_update(
        loss_fn, reconstruct=lambda b: downlink.decode_into(b[1], b[0]))

    def d_core(params, batches, weights, eta, server_state, extra):
        t_state, d_state = (extra if transport is not None
                            else (None, extra))
        ref, payload, recon, d_state, level = encode_broadcast(
            downlink, params, d_state)
        if constrain is not None:
            recon = constrain(recon)
        client_params, first_losses, last_losses = jax.vmap(
            fused, in_axes=(None, 0, None),
            spmd_axis_name=client_spmd_axes)((ref, payload), batches, eta)
        if transport is None:
            aggregate = aggregator(client_params, weights)
            new_params, server_state = server.step(recon, aggregate,
                                                   server_state, server_lr)
            return (new_params, first_losses, last_losses, server_state,
                    d_state, level)
        aggregate, t_state = transport.aggregate(
            aggregator, recon, client_params, weights, t_state)
        new_params, server_state = server.step(recon, aggregate,
                                               server_state, server_lr)
        return (new_params, first_losses, last_losses, server_state,
                (t_state, d_state), level)

    return d_core


def make_parallel_slab_cores(loss_fn: LossFn, aggregator: Aggregator,
                             server, server_lr: float, *,
                             client_spmd_axes: Optional[Sequence[str]] = None,
                             transport=None):
    """Streaming-cohort cores (DESIGN.md §11) shared by Local and
    Mesh-parallel: a round's U clients arrive as ceil(U/C) slabs of C; each
    slab folds into f32 running sums and only the finalize step touches the
    server optimizer.

    slab_core(params, batches{(C,K,b,...)}, weights(C,), eta, acc, ef)
        -> (acc, first_losses (C,), last_losses (C,), ef_out)
    finalize_core(params, acc, server_state)
        -> (new_params, server_state, new_residual)

    ``acc`` is ``(hat_acc, true_acc)``: params-shaped f32 partial sums
    (``true_acc`` is ``()`` except for aggregate-EF codecs). ``weights``
    are the slab's slice of the GLOBAL round weights (sum 1 over U, not
    over C) so partial sums compose additively and the C == U slab
    reproduces the dense round bit-for-bit. ``ef`` is the slab's
    per-client residual slice (slotted EF), the round-frozen aggregate
    residual (read back unchanged; finalize emits the new one), or ``()``.
    """
    if transport is not None and transport.name == "none":
        transport = None  # IdentityTransport == plain aggregator path
    agg_ef = (transport is not None and transport.error_feedback
              and not transport.ef_slots)
    client = make_client_update(loss_fn)

    def slab_core(params, batches, weights, eta, acc, ef):
        client_params, first_losses, last_losses = jax.vmap(
            client, in_axes=(None, 0, None),
            spmd_axis_name=client_spmd_axes)(params, batches, eta)
        hat_acc, true_acc = acc
        if transport is None:
            part = aggregator(client_params, weights)
            hat_acc = jax.tree.map(
                lambda a, p: a + p.astype(jnp.float32), hat_acc, part)
            return (hat_acc, true_acc), first_losses, last_losses, ef
        hat, true, ef = transport.aggregate_slab(
            params, client_params, weights, ef)
        hat_acc = jax.tree.map(jnp.add, hat_acc, hat)
        if agg_ef:
            true_acc = jax.tree.map(jnp.add, true_acc, true)
        return (hat_acc, true_acc), first_losses, last_losses, ef

    def finalize_core(params, acc, server_state):
        hat_acc, true_acc = acc
        if transport is None:
            # hat_acc holds sum_slabs aggregator(...) in f32; the cast is
            # the dense path's own einsum->dtype cast, deferred to round end
            aggregate = jax.tree.map(lambda a, p: a.astype(p.dtype),
                                     hat_acc, params)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return new_params, server_state, ()
        aggregate = jax.tree.map(
            lambda p, h: (p.astype(jnp.float32) + h).astype(p.dtype),
            params, hat_acc)
        new_params, server_state = server.step(params, aggregate,
                                               server_state, server_lr)
        new_res = (jax.tree.map(jnp.subtract, true_acc, hat_acc)
                   if agg_ef else ())
        return new_params, server_state, new_res

    return slab_core, finalize_core


class LocalBackend(ExecutionBackend):
    name = "local"

    def make_round_core(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        trim_fraction: float = 0.1, server=None,
                        server_lr: float = 1.0, transport=None,
                        downlink=None):
        agg = get_aggregator(aggregator, trim_fraction=trim_fraction)
        return make_parallel_round_core(loss_fn, agg, server, server_lr,
                                        transport=transport,
                                        downlink=downlink)

    def make_slab_cores(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        server=None, server_lr: float = 1.0, transport=None):
        agg = get_aggregator(aggregator)
        return make_parallel_slab_cores(loss_fn, agg, server, server_lr,
                                        transport=transport)

    def fleet_slices(self, n: int):
        """Fresh single-device backends, one per packed point: placement is
        stateless, so concurrent points interleave on the device dispatch
        queue (round-robin by arrival) while each keeps its own prefetch
        threads — the local fall-back of mesh sub-slicing (DESIGN.md §12)."""
        return [LocalBackend() for _ in range(n)]
