"""LocalBackend — single-device execution (the PR-1 engine's geometry).

The round's N clients run as a plain ``jax.vmap`` over the client axis;
aggregation is the configured Aggregator verbatim; placement is a plain
transfer. This is the degenerate point of the backend protocol: everything
``MeshBackend`` does collapses to this on a 1x1 mesh, which is exactly what
the parity tests assert (tests/test_backends.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.backends.base import ExecutionBackend, LossFn
from repro.core.engine.client import make_client_update


def encode_broadcast(downlink, params, d_state):
    """Uniform downlink-core entry point: every downlink round core emits
    ``(ref, payload, recon, new_state, level)`` — codecs without a
    per-round level (everything but ``adaptive``) get the sentinel -1,
    which the trainer reads as "charge the configured ratio"."""
    out = downlink.encode_broadcast(params, d_state)
    if len(out) == 5:
        return out
    ref, payload, recon, new_state = out
    return ref, payload, recon, new_state, jnp.int32(-1)


def make_parallel_round_core(loss_fn: LossFn, aggregator: Aggregator,
                             server, server_lr: float, *,
                             client_spmd_axes: Optional[Sequence[str]] = None,
                             transport=None, downlink=None, constrain=None):
    """The vmap-over-clients round core shared by Local and Mesh-parallel.

    ``client_spmd_axes``: mesh axes the vmapped client dim is sharded over
    (``spmd_axis_name``); None on a single device.

    round_core(params, batches{(N,K,b,...)}, weights(N,), eta, server_state)
    -> (new_params, first_losses (N,), last_losses (N,), server_state).

    With ``transport`` (DESIGN.md §8) the clients' stacked params go through
    the codec's delta pipeline (encode -> fused decompress-reduce) instead
    of the aggregator, and the core threads the transport state:
    round_core(..., server_state, t_state) -> (..., server_state, t_state).

    With ``downlink`` (DESIGN.md §10) the broadcast is *fused into the
    client forward*: the core's extra carry slot is the downlink state (or
    an ``(uplink, downlink)`` pair), the server encodes once, and each
    vmapped client reconstructs ``ref + dec(payload)`` lazily inside its
    own first step (``client.reconstruct``) — the decoded f32 tree is
    never a separate engine-materialised round input. The server-side
    reconstruction (aggregate target, next reference) is the identical
    elementwise program, so XLA CSEs the two decodes under jit. Downlink
    cores additionally return the per-round adaptive level scalar.
    ``constrain`` pins the server-side reconstruction to the backend's
    param sharding (mesh); None on a single device.
    """
    if downlink is None:
        client = make_client_update(loss_fn)

        if transport is None:
            def round_core(params, batches, weights, eta, server_state):
                client_params, first_losses, last_losses = jax.vmap(
                    client, in_axes=(None, 0, None),
                    spmd_axis_name=client_spmd_axes)(params, batches, eta)
                aggregate = aggregator(client_params, weights)
                new_params, server_state = server.step(params, aggregate,
                                                       server_state,
                                                       server_lr)
                return new_params, first_losses, last_losses, server_state

            return round_core

        def round_core(params, batches, weights, eta, server_state, t_state):
            client_params, first_losses, last_losses = jax.vmap(
                client, in_axes=(None, 0, None),
                spmd_axis_name=client_spmd_axes)(params, batches, eta)
            aggregate, t_state = transport.aggregate(
                aggregator, params, client_params, weights, t_state)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return (new_params, first_losses, last_losses, server_state,
                    t_state)

        return round_core

    # fused downlink path: the vmapped "params" argument is the broadcast
    # bundle (ref, payload), unbatched (in_axes=None) so the decode traces
    # once and is shared across clients
    fused = make_client_update(
        loss_fn, reconstruct=lambda b: downlink.decode_into(b[1], b[0]))

    def d_core(params, batches, weights, eta, server_state, extra):
        t_state, d_state = (extra if transport is not None
                            else (None, extra))
        ref, payload, recon, d_state, level = encode_broadcast(
            downlink, params, d_state)
        if constrain is not None:
            recon = constrain(recon)
        client_params, first_losses, last_losses = jax.vmap(
            fused, in_axes=(None, 0, None),
            spmd_axis_name=client_spmd_axes)((ref, payload), batches, eta)
        if transport is None:
            aggregate = aggregator(client_params, weights)
            new_params, server_state = server.step(recon, aggregate,
                                                   server_state, server_lr)
            return (new_params, first_losses, last_losses, server_state,
                    d_state, level)
        aggregate, t_state = transport.aggregate(
            aggregator, recon, client_params, weights, t_state)
        new_params, server_state = server.step(recon, aggregate,
                                               server_state, server_lr)
        return (new_params, first_losses, last_losses, server_state,
                (t_state, d_state), level)

    return d_core


class LocalBackend(ExecutionBackend):
    name = "local"

    def make_round_core(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        trim_fraction: float = 0.1, server=None,
                        server_lr: float = 1.0, transport=None,
                        downlink=None):
        agg = get_aggregator(aggregator, trim_fraction=trim_fraction)
        return make_parallel_round_core(loss_fn, agg, server, server_lr,
                                        transport=transport,
                                        downlink=downlink)
