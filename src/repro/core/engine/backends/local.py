"""LocalBackend — single-device execution (the PR-1 engine's geometry).

The round's N clients run as a plain ``jax.vmap`` over the client axis;
aggregation is the configured Aggregator verbatim; placement is a plain
transfer. This is the degenerate point of the backend protocol: everything
``MeshBackend`` does collapses to this on a 1x1 mesh, which is exactly what
the parity tests assert (tests/test_backends.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.core.engine.aggregators import Aggregator, get_aggregator
from repro.core.engine.backends.base import ExecutionBackend, LossFn
from repro.core.engine.client import make_client_update


def make_parallel_round_core(loss_fn: LossFn, aggregator: Aggregator,
                             server, server_lr: float, *,
                             client_spmd_axes: Optional[Sequence[str]] = None,
                             transport=None):
    """The vmap-over-clients round core shared by Local and Mesh-parallel.

    ``client_spmd_axes``: mesh axes the vmapped client dim is sharded over
    (``spmd_axis_name``); None on a single device.

    round_core(params, batches{(N,K,b,...)}, weights(N,), eta, server_state)
    -> (new_params, first_losses (N,), last_losses (N,), server_state).

    With ``transport`` (DESIGN.md §8) the clients' stacked params go through
    the codec's delta pipeline (encode -> fused decompress-reduce) instead
    of the aggregator, and the core threads the transport state:
    round_core(..., server_state, t_state) -> (..., server_state, t_state).
    """
    client = make_client_update(loss_fn)

    if transport is None:
        def round_core(params, batches, weights, eta, server_state):
            client_params, first_losses, last_losses = jax.vmap(
                client, in_axes=(None, 0, None),
                spmd_axis_name=client_spmd_axes)(params, batches, eta)
            aggregate = aggregator(client_params, weights)
            new_params, server_state = server.step(params, aggregate,
                                                   server_state, server_lr)
            return new_params, first_losses, last_losses, server_state

        return round_core

    def round_core(params, batches, weights, eta, server_state, t_state):
        client_params, first_losses, last_losses = jax.vmap(
            client, in_axes=(None, 0, None),
            spmd_axis_name=client_spmd_axes)(params, batches, eta)
        aggregate, t_state = transport.aggregate(
            aggregator, params, client_params, weights, t_state)
        new_params, server_state = server.step(params, aggregate,
                                               server_state, server_lr)
        return (new_params, first_losses, last_losses, server_state,
                t_state)

    return round_core


class LocalBackend(ExecutionBackend):
    name = "local"

    def make_round_core(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        trim_fraction: float = 0.1, server=None,
                        server_lr: float = 1.0, transport=None):
        agg = get_aggregator(aggregator, trim_fraction=trim_fraction)
        return make_parallel_round_core(loss_fn, agg, server, server_lr,
                                        transport=transport)
