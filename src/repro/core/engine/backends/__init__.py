"""Execution backends: where/how a round's client fan-out runs (DESIGN.md §7)."""
from repro.api.registries import BACKEND_REGISTRY, register_backend
from repro.core.engine.backends.base import (ExecutionBackend,
                                             LINEAR_AGGREGATORS)
from repro.core.engine.backends.local import (LocalBackend,
                                              make_parallel_round_core)
from repro.core.engine.backends.mesh import MeshBackend

BACKENDS = ("local", "mesh")   # builtins


def _local_factory(**kw):
    return LocalBackend()


def _mesh_factory(*, mesh=None, strategy: str = "parallel", groups: int = 1,
                  reduce: str = "flat", **kw):
    """Default mesh: all host devices on a (devices, 1) data x model mesh —
    the geometry ``launch/train.py --backend mesh`` always used. Pass a
    concrete ``mesh`` to control the topology."""
    import jax
    if mesh is None:
        n_dev = len(jax.devices())
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    return MeshBackend(mesh, strategy=strategy, groups=groups, reduce=reduce)


# builtin registrations — factory signature: f(*, strategy, groups, **kw)
register_backend("local", _local_factory)
register_backend("mesh", _mesh_factory)


def get_backend(name, **kw) -> ExecutionBackend:
    """Resolve a backend through the plugin registry; an
    ``ExecutionBackend`` instance passes through."""
    if isinstance(name, ExecutionBackend):
        return name
    return BACKEND_REGISTRY.get(name)(**kw)


__all__ = ["ExecutionBackend", "LINEAR_AGGREGATORS", "LocalBackend",
           "MeshBackend", "make_parallel_round_core", "BACKENDS",
           "get_backend"]
