"""Execution backends: where/how a round's client fan-out runs (DESIGN.md §7)."""
from repro.core.engine.backends.base import (ExecutionBackend,
                                             LINEAR_AGGREGATORS)
from repro.core.engine.backends.local import (LocalBackend,
                                              make_parallel_round_core)
from repro.core.engine.backends.mesh import MeshBackend

__all__ = ["ExecutionBackend", "LINEAR_AGGREGATORS", "LocalBackend",
           "MeshBackend", "make_parallel_round_core"]
