"""ExecutionBackend — where and how a federated round's client fan-out runs.

The round *semantics* (ClientUpdate -> Aggregator -> ServerOptimizer, see
DESIGN.md §6) are backend-independent; an ExecutionBackend decides the
*execution geometry*:

  * how the client axis of a round executes (single-device ``vmap``, mesh
    ``vmap`` with ``spmd_axis_name``, or a grouped sequential scan),
  * which concrete aggregation implementation runs (plain einsum, Pallas
    kernel, or the client-sharded Pallas kernel with an all-reduce of
    per-shard partials),
  * how host tensors are placed on device (plain transfer vs ``device_put``
    with the backend's client sharding, issued from the prefetch thread so
    the H2D copy overlaps device compute).

``RoundEngine`` composes a backend's round core into the K-bucketed
multi-round scan and AOT-compiles one executable per input signature
(DESIGN.md §7) — so every schedule, server optimizer and robust aggregator
works identically on a laptop CPU and on a GSPMD-sharded pod.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Any]

# aggregators that are linear in the client stack: a sequential backend can
# stream them as a running weighted sum instead of materialising the
# (N, ...) stack (kernel == mean contraction, just a different implementation)
LINEAR_AGGREGATORS = ("mean", "kernel")


def axes_size(mesh, axes) -> int:
    """Product of the named mesh axes' sizes (1 for no mesh / no axes)."""
    if mesh is None or not axes:
        return 1
    size = 1
    for a in axes:
        size *= mesh.shape[a] if a in mesh.axis_names else 1
    return size


class ExecutionBackend:
    """Protocol + shared no-op placement defaults (single-device behaviour).

    Subclasses must implement ``make_round_core``; placement hooks are
    optional and must be idempotent (placing an already-placed array is a
    no-op) so ``RoundEngine.run_bucket`` can call them unconditionally.
    """

    name: str = "base"

    # ------------------------------------------------------------------
    # round core construction
    # ------------------------------------------------------------------
    def make_round_core(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        trim_fraction: float = 0.1, server=None,
                        server_lr: float = 1.0, transport=None,
                        downlink=None):
        """Return round_core(params, batches{(N,K,b,...)}, weights(N,), eta,
        server_state) -> (new_params, first_losses(N,), last_losses(N,),
        server_state).

        With a non-None ``transport`` (DESIGN.md §8) the core gains a
        trailing transport-state argument/result: round_core(params,
        batches, weights, eta, server_state, t_state) -> (new_params,
        first_losses, last_losses, server_state, t_state).

        With a non-None ``downlink`` (DESIGN.md §10) the trailing slot is
        the downlink state — or the ``(t_state, d_state)`` pair when both
        codecs run — the broadcast is decoded lazily inside the client
        step, and the core returns one more element: the per-round
        adaptive-level int32 scalar (-1 for fixed-rate codecs)."""
        raise NotImplementedError

    def make_slab_cores(self, loss_fn: LossFn, *, aggregator: str = "mean",
                        server=None, server_lr: float = 1.0, transport=None):
        """Return ``(slab_core, finalize_core)`` for chunked streaming
        cohorts (DESIGN.md §11):

        slab_core(params, batches{(C,K,b,...)}, weights(C,), eta, acc, ef)
            -> (acc, first_losses(C,), last_losses(C,), ef_out)
        finalize_core(params, acc, server_state)
            -> (new_params, server_state, new_residual)

        ``acc = (hat_acc, true_acc)`` are params-shaped f32 running sums
        (``true_acc`` is ``()`` except for aggregate-EF transports);
        ``weights`` are the slab's slice of the global round weights.
        Backends whose execution geometry cannot stream slabs (grouped
        sequential scans fold clients themselves) raise ValueError."""
        raise NotImplementedError(
            f"backend {self.name!r} does not support chunked streaming "
            f"cohorts (cohort_chunk)")

    # ------------------------------------------------------------------
    # placement (host -> device, with the backend's shardings)
    # ------------------------------------------------------------------
    def place_params(self, params: PyTree) -> PyTree:
        return jax.tree.map(jnp.asarray, params)

    def place_batches(self, batches: Dict[str, Any]) -> Dict[str, Any]:
        """Bucket batch tensors, leaves (B, N, K, b, ...)."""
        return {k: jnp.asarray(v) for k, v in batches.items()}

    def place_weights(self, weights) -> jnp.ndarray:
        """Bucket weights (B, N)."""
        return jnp.asarray(weights, jnp.float32)

    def place_scalars(self, etas, active):
        return jnp.asarray(etas, jnp.float32), jnp.asarray(active, bool)

    def place_bucket(self, bb):
        """Place a ``pipeline.BucketBatch`` in one call — used as the
        prefetcher's ``place_fn`` so transfers start on the build thread."""
        return dataclasses.replace(
            bb, batches=self.place_batches(bb.batches),
            weights=self.place_weights(bb.weights),
            active=jnp.asarray(bb.active, bool))

    def place_slab(self, sb):
        """Place a ``pipeline.SlabBatch`` (leaves (C, K, b, ...), weights
        (C,)) — the streaming-cohort analogue of ``place_bucket``, also
        used as the prefetcher's ``place_fn`` so the next slab's H2D copy
        overlaps the current slab's compute (DESIGN.md §11). Idempotent."""
        return dataclasses.replace(
            sb, batches={k: jnp.asarray(v) for k, v in sb.batches.items()},
            weights=jnp.asarray(sb.weights, jnp.float32))

    def place_transport_state(self, state, per_client: bool = False):
        """Transport error-feedback state. Aggregate-level state is
        params-shaped and rides the params placement (sharding specs
        included); ``per_client`` state carries a leading cohort axis
        (DESIGN.md §9.3) that params shardings must not be applied to."""
        if not jax.tree.leaves(state):
            return state
        if per_client:
            return jax.tree.map(jnp.asarray, state)
        return self.place_params(state)

    def place_downlink_state(self, state):
        """Downlink broadcast state (DESIGN.md §8.6): the reference params
        and the downlink EF residual are params-shaped under the default
        f32 store, so each rides the params placement (sharding specs
        included). Under the quantised q8 store (DESIGN.md §10.3) the
        leaves are int8/scale dicts that params shardings don't apply to —
        those fall back to a plain transfer."""
        if not state:                       # () when downlink is off
            return state

        def place(tree):
            if not jax.tree.leaves(tree):
                return tree
            try:
                return self.place_params(tree)
            except (ValueError, TypeError, KeyError):
                return jax.tree.map(jnp.asarray, tree)

        return {"ref": place(state["ref"]), "res": place(state["res"])}

    # ------------------------------------------------------------------
    # fleet packing (DESIGN.md §12)
    # ------------------------------------------------------------------
    def fleet_slices(self, n: int):
        """Return ``n`` backends for packing ``n`` concurrent sweep points.

        Default: this backend, shared — correct for any backend whose
        placement is stateless, but concurrent points then contend for the
        same devices. Subclasses carve real slices (LocalBackend: fresh
        interleaved instances; MeshBackend: sub-meshes)."""
        return [self] * n

    # ------------------------------------------------------------------
    # codec binding
    # ------------------------------------------------------------------
    def bind_downlink(self, codec):
        """Backend hook: bind a ``DownlinkCodec`` to the execution geometry
        (MeshBackend routes decode-apply through the sharded kernel).
        Identity on a single device; must accept/return None."""
        return codec

    # ------------------------------------------------------------------
    # output sharding pinning
    # ------------------------------------------------------------------
    def constrain_update(self, tree: PyTree) -> PyTree:
        """Pin the bucket executable's params-like outputs (new params,
        transport state) to the backend's parameter sharding, so the next
        bucket's ``place_params`` is a no-op instead of a per-bucket
        canonicalising ``device_put`` (DESIGN.md §7.3). No-op on a single
        device."""
        return tree

    def constrain_transport_update(self, tree: PyTree,
                                   per_client: bool = False) -> PyTree:
        """``constrain_update`` for the executable's transport-state output.
        Per-client EF state (leading cohort axis) must not take the params
        shardings — a leading-dims PartitionSpec would silently shard the
        cohort axis with the param's first-dim spec."""
        if per_client:
            return tree
        return self.constrain_update(tree)
