"""RoundScheduler — groups rounds into K-buckets for amortised execution.

A *bucket* is a run of consecutive rounds that (a) share one (quantized) K,
(b) fits the configured bucket length, and (c) does not cross an eval
boundary (eval needs host params, which only exist between buckets). Each
bucket executes as one jitted multi-round scan (`engine.round`).

Two planning modes (DESIGN.md §6.4):

* **loss-free** — both schedules are pure functions of the round index
  (K in {fixed, dsgd, rounds, cosine}, eta in {fixed, rounds}).  The whole
  plan is computed upfront, so the batch prefetcher can build bucket r+1
  while bucket r runs on device, and the trainer never syncs mid-bucket.
* **feedback** — error/step schedules need loss/validation signals, which
  are only observed at bucket boundaries.  Buckets are planned lazily, one
  at a time, with length ``fed.feedback_bucket_rounds`` (default 1, which
  reproduces the per-round feedback of the seed loop exactly; larger values
  trade schedule staleness for dispatch amortisation).

Executable-shape policy (bounds compiles to the K grid): each K gets
exactly ONE executable length — the full bucket length if any of its
segments is long enough to amortise it, else 1 (per-round dispatch, i.e.
exactly the seed loop's cost).  Short tails of long runs are padded with
masked-out rounds rather than given a second shape, so the engine's compile
cache holds at most one entry per distinct quantized K.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

from repro.configs.base import FedConfig
from repro.core.schedules import DecayController

LOSS_FREE_K = ("fixed", "dsgd", "rounds", "cosine")
LOSS_FREE_ETA = ("fixed", "rounds")


@dataclass(frozen=True)
class Bucket:
    rounds: List[int]        # 1-based round indices executed (active)
    k: int                   # shared local-step count
    etas: List[float]        # per-round client learning rates
    shape_rounds: int        # executable leading dim (>= len(rounds))
    eval_after: bool         # trainer should eval at this bucket's end
    serve_after: bool = False  # serving tick at this bucket's end (§14)

    def __len__(self) -> int:
        return len(self.rounds)


def is_loss_free(fed: FedConfig) -> bool:
    return (fed.k_schedule in LOSS_FREE_K
            and fed.eta_schedule in LOSS_FREE_ETA)


class RoundScheduler:
    def __init__(self, ctrl: DecayController, fed: FedConfig, *,
                 total_rounds: int, eval_every: Optional[int] = None,
                 serve_every: Optional[int] = None, start_round: int = 1):
        """``eval_every`` of None means no eval_fn: no eval cut points.
        ``serve_every`` of None/0 means no serving loop: no serve cut
        points (the plan — and hence every executable shape — is untouched,
        keeping serve-off programs bit-for-bit).  With serving on, buckets
        additionally cut at ``serve_every`` multiples so the trainer can
        absorb + hot-swap immediately, bounding served-version staleness
        at one round (DESIGN.md §14).
        ``start_round`` > 1 resumes a checkpointed run mid-schedule: rounds
        [start_round, total_rounds] are planned with their *absolute*
        indices, so round-indexed K/eta schedules and eval cut points are
        identical to the uninterrupted run's."""
        self.ctrl = ctrl
        self.fed = fed
        self.total_rounds = total_rounds
        self.start_round = max(int(start_round), 1)
        self.eval_every = eval_every
        self.serve_every = serve_every or None
        self.loss_free = is_loss_free(fed)
        cap = max(fed.bucket_rounds if self.loss_free
                  else fed.feedback_bucket_rounds, 1)
        if eval_every is not None:
            cap = min(cap, max(eval_every, 1))
        if self.serve_every is not None:
            cap = min(cap, max(self.serve_every, 1))
        if getattr(fed, "cohort_chunk", None):
            # streaming cohorts (DESIGN.md §11) dispatch slab-by-slab within
            # a round — the multi-round bucket scan doesn't apply, so every
            # bucket is exactly one round
            cap = 1
        self.bucket_cap = cap

    # ------------------------------------------------------------------
    def _is_eval_round(self, r: int) -> bool:
        if self.eval_every is None:
            return False
        return r % self.eval_every == 0 or r == self.total_rounds

    def _is_serve_round(self, r: int) -> bool:
        return self.serve_every is not None and r % self.serve_every == 0

    def _cut_after(self, r: int) -> bool:
        """Must the bucket containing round r end at r?"""
        return (self._is_eval_round(r) or self._is_serve_round(r)
                or r == self.total_rounds)

    # ------------------------------------------------------------------
    def _segments(self) -> List[List[int]]:
        """Maximal constant-K stretches between cut points (loss-free)."""
        segs: List[List[int]] = []
        cur: List[int] = []
        k_prev = None
        for r in range(self.start_round, self.total_rounds + 1):
            k = self.ctrl.k_for_round(r)
            if cur and k != k_prev:
                segs.append(cur)
                cur = []
            cur.append(r)
            k_prev = k
            if self._cut_after(r):
                segs.append(cur)
                cur = []
        if cur:
            segs.append(cur)
        return segs

    def _best_shape(self, seg_lens: List[int]) -> int:
        """One executable length for a K, given its segment lengths: minimise
        computed rounds (padding) plus one round-equivalent per dispatch (the
        amortisation the bucket exists for), preferring longer shapes on
        ties.  E.g. segments of 10 with cap 8 pick 5 (zero padding), a lone
        2-round segment picks 2, and a 23-round run picks 8 (one padded
        tail) rather than degenerating to per-round dispatch."""
        def cost(s: int) -> tuple:
            computed = sum((l + s - 1) // s * s for l in seg_lens)
            dispatches = sum((l + s - 1) // s for l in seg_lens)
            return (computed + dispatches, -s)

        return min(range(1, self.bucket_cap + 1), key=cost)

    def _plan_loss_free(self) -> Iterator[Bucket]:
        segs = self._segments()
        seg_lens: Dict[int, List[int]] = {}
        for seg in segs:
            k = self.ctrl.k_for_round(seg[0])
            seg_lens.setdefault(k, []).append(len(seg))
        shape_for_k = {k: self._best_shape(lens)
                       for k, lens in seg_lens.items()}
        for seg in segs:
            k = self.ctrl.k_for_round(seg[0])
            shape = shape_for_k[k]
            for i in range(0, len(seg), shape):
                rounds = seg[i:i + shape]
                yield Bucket(rounds=rounds, k=k,
                             etas=[self.ctrl.eta_for_round(r) for r in rounds],
                             shape_rounds=shape,
                             eval_after=self._is_eval_round(rounds[-1]),
                             serve_after=self._is_serve_round(rounds[-1]))

    def _plan_feedback(self) -> Iterator[Bucket]:
        r = self.start_round
        while r <= self.total_rounds:
            k = self.ctrl.k_for_round(r)
            rounds, etas = [r], [self.ctrl.eta_for_round(r)]
            while (not self._cut_after(rounds[-1])
                   and len(rounds) < self.bucket_cap):
                nxt = rounds[-1] + 1
                # the controller state is frozen between observations, so
                # this only cuts on round-indexed K changes (e.g.
                # k_schedule='rounds' with eta_schedule='error')
                if self.ctrl.k_for_round(nxt) != k:
                    break
                rounds.append(nxt)
                etas.append(self.ctrl.eta_for_round(nxt))
            yield Bucket(rounds=rounds, k=k, etas=etas,
                         shape_rounds=self.bucket_cap,
                         eval_after=self._is_eval_round(rounds[-1]),
                         serve_after=self._is_serve_round(rounds[-1]))
            r = rounds[-1] + 1

    def plan(self) -> Iterator[Bucket]:
        """Yield buckets in execution order.

        Feedback-mode buckets are planned lazily: each ``next()`` consults
        the controller, so the trainer must feed observations (losses /
        validation) for bucket i before requesting bucket i+1.
        """
        if self.loss_free:
            return self._plan_loss_free()
        return self._plan_feedback()
