"""Layered FedAvg round engine (DESIGN.md §6).

    ClientUpdate -> Aggregator -> ServerOptimizer      (one round)
    RoundScheduler -> K-buckets -> RoundEngine scan    (many rounds, few compiles)
    BatchPrefetcher                                    (host/device overlap)
"""
from repro.core.engine.aggregators import (AGGREGATORS, get_aggregator,
                                           weighted_mean)
from repro.core.engine.backends import (ExecutionBackend, LocalBackend,
                                        MeshBackend)
from repro.core.engine.client import ClientResult, client_update, \
    make_client_update
from repro.core.engine.round import (RoundEngine, make_bucket_fn,
                                     make_round_core, make_round_fn,
                                     make_transport_bucket_fn)
from repro.core.engine.sampling import (SAMPLERS, AvailabilitySampler,
                                        ClientSampler, FixedCohortSampler,
                                        UniformSampler, WeightedSampler,
                                        get_sampler, make_sampler)
from repro.core.engine.scheduler import Bucket, RoundScheduler, is_loss_free
from repro.core.engine.server import (SERVER_OPTIMIZERS, ServerOptimizer,
                                      get_server_optimizer)
from repro.core.engine.async_buffer import (AsyncBufferedEngine,
                                            STALENESS_WEIGHTS,
                                            get_staleness_weight)
from repro.core.engine.trainer import FedAvgTrainer, History, make_eval_fn
from repro.core.engine.transport import (TRANSPORTS, AdaptiveDownlinkCodec,
                                         DownlinkCodec, IdentityTransport,
                                         Int8Transport, TopKTransport,
                                         Transport, get_downlink,
                                         get_transport)

__all__ = ["AsyncBufferedEngine", "STALENESS_WEIGHTS",
           "get_staleness_weight",
           "AGGREGATORS", "get_aggregator", "weighted_mean",
           "ExecutionBackend", "LocalBackend", "MeshBackend", "ClientResult",
           "client_update", "make_client_update", "RoundEngine",
           "make_bucket_fn", "make_round_core", "make_round_fn",
           "make_transport_bucket_fn", "Bucket",
           "RoundScheduler", "is_loss_free", "SERVER_OPTIMIZERS",
           "ServerOptimizer", "get_server_optimizer", "FedAvgTrainer",
           "History", "make_eval_fn", "TRANSPORTS", "Transport",
           "AdaptiveDownlinkCodec",
           "DownlinkCodec", "IdentityTransport", "Int8Transport",
           "TopKTransport", "get_downlink",
           "get_transport", "SAMPLERS", "ClientSampler", "UniformSampler",
           "WeightedSampler", "FixedCohortSampler", "AvailabilitySampler",
           "get_sampler", "make_sampler"]
