"""Peak-memory accounting for compiled round executables (DESIGN.md §11).

XLA's ``CompiledMemoryStats`` (via ``executable.memory_analysis()``) reports,
per compiled executable, the bytes it holds live: arguments, outputs and the
internal temp buffer high-water mark. For the round engine that IS the
device-memory story — every round/bucket/slab runs as exactly one registry
executable — so "peak HBM of a round" reduces to a max over the engine's
executable registry, measured without running anything.

This is the measurement the chunked-streaming acceptance rides on: a round
of U clients in C-sized slabs must peak at O(C) client state, not O(U)
(``benchmarks/schedules_bench.py`` cohort_stream rows, tests/test_streaming
memory budget).
"""
from __future__ import annotations

from typing import Any

PyTree = Any


def executable_peak_bytes(exe) -> int:
    """Live bytes for one compiled executable: arguments + outputs + the
    temp high-water mark, minus donated/aliased double counting. Returns 0
    when the runtime doesn't expose memory stats (non-XLA backends)."""
    try:
        ma = exe.memory_analysis()
    except Exception:                      # pragma: no cover - runtime-dep
        return 0
    return int(getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0)
               - getattr(ma, "alias_size_in_bytes", 0))


def executable_peak_mb(exe) -> float:
    return executable_peak_bytes(exe) / 1e6


def engine_peak_mb(engine) -> float:
    """Max peak MB across a ``RoundEngine``'s compiled executables — the
    device high-water mark a training loop driven by that engine reaches
    (dispatches are sequential; at most one registry executable is live).
    0.0 before anything compiled."""
    peaks = [executable_peak_bytes(e)
             for e in getattr(engine, "_executables", {}).values()]
    return max(peaks) / 1e6 if peaks else 0.0


def trainer_peak_mb(trainer) -> float:
    """``engine_peak_mb`` of a trainer's engine."""
    return engine_peak_mb(trainer.engine)
