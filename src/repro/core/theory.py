"""The paper's theory, executable: Theorem 1 bound, Theorem 2 optimal K_w*,
Corollary 2.1 optimal eta_w*, and the Eq. 10/12 round-form schedules.

These are used (a) by tests that verify the schedules follow from the
theorems (K* ~ w^{-1/3}, eta* ~ w^{-1/2}), and (b) by the strongly-convex
validation experiment that checks Theorem 1's bound actually upper-bounds
measured gradient norms on a quadratic problem.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class ProblemConstants:
    """Assumption 1-3 constants for a concrete objective."""
    L: float            # smoothness
    mu: float           # strong convexity
    sigma_sq: float     # sum_c p_c^2 sigma_c^2
    gamma: float        # Gamma = F* - sum_c p_c f_c*   (non-IID-ness)
    g_sq: float         # G^2 = L^2 ||x_1 - x*||^2
    f0: float           # F(x_0)
    f_star: float       # F*
    n_clients: int      # N participating per round

    @property
    def kappa(self) -> float:
        return self.L / self.mu


def theorem1_bound(pc: ProblemConstants, eta: float,
                   ks: Sequence[int]) -> float:
    """Eq. 6: bound on min_t E||grad F(x_bar_t)||^2 after sum(ks) iterations."""
    t_total = float(sum(ks))
    sum_k3 = float(sum(k ** 3 for k in ks))
    sum_k = float(sum(ks))
    kap = pc.kappa
    term1 = 2 * kap * (kap * pc.f0 - pc.f_star) / (eta * t_total)
    drift = (8 + 4 / pc.n_clients) * pc.g_sq * (sum_k3 / sum_k)
    term2 = eta * kap * pc.L * (pc.sigma_sq + 6 * pc.L * pc.gamma + drift)
    return term1 + term2


def optimal_k(pc: ProblemConstants, eta: float, f_current: float,
              comm_time_s: float, horizon_s: float) -> float:
    """Theorem 2 / Eq. 9: optimal fixed K looking forward from now.

    comm_time_s = |x|/D + |x|/U; horizon_s = remaining wall-clock budget W.
    """
    num = pc.kappa * f_current - pc.f_star
    den = 8 * eta ** 2 * pc.L * (1 + 1 / (2 * pc.n_clients)) * pc.g_sq
    return (max(num, 0.0) / den * comm_time_s / horizon_s) ** (1.0 / 3.0)


def optimal_k_rounds(pc: ProblemConstants, eta: float, rounds: int) -> float:
    """Eq. 10: communication-dominated reformulation (K* indep. of beta)."""
    num = pc.kappa * pc.f0 - pc.f_star
    den = 8 * eta ** 2 * pc.L * (1 + 1 / (2 * pc.n_clients)) * pc.g_sq
    return (max(num, 0.0) / den / rounds) ** (1.0 / 3.0)


def optimal_eta(pc: ProblemConstants, k: int, f_current: float,
                comm_time_s: float, beta_s: float, horizon_s: float) -> float:
    """Corollary 2.1 / Eq. 11."""
    z = pc.sigma_sq + 6 * pc.L * pc.gamma + (8 + 4 / pc.n_clients) * pc.g_sq * k ** 2
    num = 2 * pc.kappa * (pc.kappa * f_current - pc.f_star)
    inner = num / (pc.kappa * pc.L * z) * (comm_time_s + beta_s * k) / (horizon_s * k)
    return math.sqrt(max(inner, 0.0))


def optimal_eta_rounds(pc: ProblemConstants, k: int, rounds: int) -> float:
    """Eq. 12."""
    z = pc.sigma_sq + 6 * pc.L * pc.gamma + (8 + 4 / pc.n_clients) * pc.g_sq * k ** 2
    num = 2 * (pc.kappa * pc.f0 - pc.f_star)
    return math.sqrt(max(num / (pc.L * z) / rounds, 0.0))
