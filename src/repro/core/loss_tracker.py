"""Eq. 15 rolling global-loss estimator + validation plateau detector.

Clients report f_c(x_r, xi_{c,0}) — the training loss of the *global* model on
their first local minibatch (an unbiased estimate of F(x_r), one float per
client per round, negligible communication). Because only a small non-IID
fraction of clients participates per round, the per-round mean is high
variance; the paper smooths with a window of s=100 rounds.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Optional


class LossTracker:
    def __init__(self, window: int = 100):
        self.window = window
        self._buf: Deque[float] = deque(maxlen=window)

    def push(self, round_mean_loss: float) -> None:
        self._buf.append(float(round_mean_loss))

    @property
    def full(self) -> bool:
        return len(self._buf) >= self.window

    def value(self) -> float:
        """Rolling mean over the last s rounds (Eq. 15)."""
        if not self._buf:
            raise ValueError("no losses observed yet")
        return sum(self._buf) / len(self._buf)


class PlateauDetector:
    """Plateau when the best validation error hasn't improved by more than
    ``min_delta`` for ``patience`` consecutive observations."""

    def __init__(self, patience: int = 50, min_delta: float = 1e-4):
        self.patience = patience
        self.min_delta = min_delta
        self.best: Optional[float] = None
        self.stale = 0
        self.plateaued = False

    def push(self, val_error: float) -> None:
        v = float(val_error)
        if self.best is None or v < self.best - self.min_delta:
            self.best = v
            self.stale = 0
        else:
            self.stale += 1
            if self.stale >= self.patience:
                self.plateaued = True
