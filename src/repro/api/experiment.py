"""FederatedExperiment — build, run and resume an ExperimentSpec.

``build(spec)`` is the one composition root for the whole system: it turns
the declarative spec into data, model, loss, runtime model, backend,
sampler and a configured ``FedAvgTrainer`` — exactly the wiring
``launch/train.py`` used to do ad-hoc (and now does through this facade).
The construction is deterministic in the spec: two ``build`` calls on equal
specs produce bitwise-identical training runs (tests/test_api.py holds this
against directly-constructed trainers across backends x transports x
samplers).

Checkpoints written by ``FederatedExperiment.save`` embed the spec, so
``FederatedExperiment.restore(path)`` rebuilds the exact trainer — no
side-channel config needed to continue a run (DESIGN.md §9.4).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

from repro.api.spec import ExperimentSpec
from repro.configs.base import FedConfig, RuntimeModelConfig

PyTree = Any


def _make_fed_config(spec: ExperimentSpec) -> FedConfig:
    f, s, t = spec.fed, spec.sampler, spec.transport
    return FedConfig(
        total_clients=spec.data.clients,
        clients_per_round=f.clients_per_round,
        rounds=f.rounds, k0=f.k0, eta0=f.eta0, batch_size=f.batch_size,
        k_schedule=f.k_schedule, eta_schedule=f.eta_schedule,
        loss_window=f.loss_window, plateau_patience=f.plateau_patience,
        step_decay_factor=f.step_decay_factor, k_min=f.k_min,
        k_quantize=f.k_quantize, k_grid0=f.k_grid0,
        server_optimizer=f.server_optimizer,
        server_lr=f.server_lr, seed=f.seed,
        aggregator=f.aggregator, trim_fraction=f.trim_fraction,
        transport=t.name, topk_frac=t.topk_frac, downlink=t.downlink,
        downlink_ref=t.ref_store,
        sampler=s.name, cohort=s.cohort, availability=s.availability,
        population=s.population, day_rounds=s.day_rounds,
        base_availability=s.base_availability,
        bucket_rounds=f.bucket_rounds,
        feedback_bucket_rounds=f.feedback_bucket_rounds,
        prefetch=f.prefetch, cohort_chunk=f.cohort_chunk,
        aggregation=f.aggregation, buffer_size=f.buffer_size,
        staleness_weight=f.staleness_weight, max_staleness=f.max_staleness)


def _make_backend(spec: ExperimentSpec):
    from repro.core.engine.backends import get_backend
    b = spec.backend
    return get_backend(b.name, strategy=b.strategy, groups=b.groups,
                       reduce=b.reduce)


def _build_task(spec: ExperimentSpec):
    """(data, loss_fn, params, model_size_mbit, label) for the spec's data
    kind. The 'lm' branch reproduces ``launch/train.py``'s historical
    construction verbatim (rng seeding order included) — the legacy-flag
    bitwise-parity contract depends on it."""
    import jax

    if spec.data.kind == "paper":
        from repro.configs import get_paper_task
        from repro.data import make_paper_task
        from repro.models import small
        task = get_paper_task(spec.data.task)
        data = make_paper_task(spec.data.task,
                               np.random.default_rng(spec.data.seed),
                               num_clients=spec.data.clients,
                               samples_per_client=spec.data.samples_per_client)
        loss_fn = lambda p, b: small.task_loss(p, task, b)
        params = small.init_task_model(jax.random.PRNGKey(spec.fed.seed), task)
        return data, loss_fn, params, task.model_size_mb, task.name

    from repro.configs import get_arch
    from repro.data import make_lm_clients
    from repro.models import registry
    cfg = get_arch(spec.model.arch)
    if spec.model.reduced:
        cfg = cfg.reduced()
    data = make_lm_clients(np.random.default_rng(spec.data.seed),
                           num_clients=spec.data.clients,
                           vocab=cfg.vocab_size, seq_len=spec.data.seq_len,
                           samples_per_client=spec.data.samples_per_client)
    model_loss = registry.loss_fn(cfg, moe_path=spec.model.moe_path)
    loss_fn = lambda p, b: model_loss(p, {"tokens": b["x"]})
    params = registry.init(jax.random.PRNGKey(spec.fed.seed), cfg)
    n_params = registry.param_count(cfg)
    size_mbit = n_params * spec.runtime.bytes_per_param * 8 / 1e6
    return data, loss_fn, params, size_mbit, cfg.name


class FederatedExperiment:
    """A built experiment: spec + trainer + (optional) eval hook.

    Not constructed directly — use ``build(spec)`` or
    ``FederatedExperiment.restore(checkpoint_path)``."""

    def __init__(self, spec: ExperimentSpec, trainer, label: str):
        self.spec = spec
        self.trainer = trainer
        self.label = label

    # ------------------------------------------------------------------
    @property
    def history(self):
        return self.trainer.history

    @property
    def params(self) -> PyTree:
        return self.trainer.params

    def _eval_every(self) -> Optional[int]:
        """``fed.eval_every == 0`` means no evaluation pass — map it to the
        scheduler's no-eval-cut-points sentinel (None), so the contract
        holds even if an eval_fn is attached to the trainer afterwards."""
        return self.spec.fed.eval_every if self.spec.fed.eval_every > 0 \
            else None

    def run(self, rounds: Optional[int] = None, *, verbose: bool = False):
        """Run the schedule (default: ``spec.fed.rounds``)."""
        return self.trainer.run(rounds if rounds is not None
                                else self.spec.fed.rounds,
                                eval_every=self._eval_every(),
                                verbose=verbose)

    def resume(self, checkpoint: str, rounds: Optional[int] = None, *,
               verbose: bool = False):
        """Restore trainer state from ``checkpoint`` and continue from the
        first unexecuted round (bitwise-identical to an uninterrupted
        run)."""
        self.trainer.restore_state(checkpoint)
        return self.trainer.run(rounds if rounds is not None
                                else self.spec.fed.rounds,
                                eval_every=self._eval_every(), verbose=verbose,
                                resume=True)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        """Full-state checkpoint with the spec embedded: ``restore(path)``
        rebuilds this exact experiment and continues it."""
        self.trainer.save_state(path, extra_meta={"spec": self.spec.as_dict()})

    @classmethod
    def restore(cls, path: str) -> "FederatedExperiment":
        """Rebuild the experiment from the spec inside a checkpoint and load
        its state. Continue with ``exp.trainer.run(..., resume=True)`` or
        simply ``exp.resume(path)``-free ``run`` wrappers."""
        with open(os.path.join(path, "meta.json")) as f:
            meta: Dict[str, Any] = json.load(f)
        if "spec" not in meta:
            raise ValueError(f"checkpoint {path!r} has no embedded spec "
                             f"(written by a pre-spec save_state?)")
        spec = ExperimentSpec.from_dict(meta["spec"])
        exp = build(spec)
        exp.trainer.restore_state(path)
        return exp


def build(spec: ExperimentSpec, *, backend=None, registry=None,
          program_key=None) -> FederatedExperiment:
    """Validate the spec and compose the experiment it describes.

    ``backend``: an already-constructed ``ExecutionBackend`` overriding the
    spec's backend section — the fleet driver passes mesh slices / fresh
    local backends per packed point (DESIGN.md §12).

    ``registry``: a shared ``ExecutableRegistry`` for cross-experiment AOT
    executable reuse. ``program_key`` defaults to
    ``sweep.spec_program_key(spec)`` when a registry is given; pass an
    explicit key to extend it (e.g. with mesh-slice device ids)."""
    from repro.api.registries import AGGREGATION_REGISTRY
    from repro.core.engine.trainer import make_eval_fn
    from repro.core.runtime_model import RuntimeModel

    spec.validate()
    if registry is not None and program_key is None:
        from repro.api.sweep import spec_program_key
        program_key = spec_program_key(spec)
    data, loss_fn, params, size_mbit, label = _build_task(spec)
    if (spec.sampler.name == "population" and spec.sampler.population
            and spec.sampler.population != data.num_clients):
        # virtual 10^6+ id space over the materialised clients — the
        # sampler draws O(cohort) ids, the view resolves them lazily
        from repro.data import PopulationView
        data = PopulationView(data, spec.sampler.population)
    fed = _make_fed_config(spec)
    r = spec.runtime
    runtime = RuntimeModel(
        size_mbit,
        RuntimeModelConfig(download_mbps=r.download_mbps,
                           upload_mbps=r.upload_mbps,
                           beta_seconds=r.beta_seconds,
                           bytes_per_param=r.bytes_per_param),
        fed.clients_per_round, heterogeneity=r.heterogeneity,
        serve_qps=spec.serve.qps, serve_query_s=spec.serve.query_ms / 1e3)
    if backend is None:
        backend = _make_backend(spec)
    eval_fn = (make_eval_fn(loss_fn, data)
               if spec.fed.eval_every > 0 else None)
    # AggregationPolicy axis (DESIGN.md §13): "sync" resolves to the
    # FedAvgTrainer construction verbatim — same class, same arguments, same
    # compiled programs — so the default path stays bit-for-bit; "async"
    # builds the AsyncBufferedEngine on the same surface.
    policy = AGGREGATION_REGISTRY.get(fed.aggregation)()
    trainer = policy(loss_fn, params, data, fed, runtime,
                     eval_fn=eval_fn, backend=backend,
                     registry=registry, program_key=program_key)
    if spec.serve.every > 0:
        # serve-while-training (DESIGN.md §14): the loop reads the trainer's
        # GlobalModelStore — a host-side attach, no traced program changes
        from repro.configs import get_arch
        from repro.core.serve import ServingLoop
        cfg = get_arch(spec.model.arch)
        if spec.model.reduced:
            cfg = cfg.reduced()
        trainer.serving = ServingLoop(
            trainer.store, cfg, batch=spec.serve.batch,
            prompt_len=spec.serve.prompt_len, tokens=spec.serve.tokens,
            moe_path=spec.model.moe_path, traffic=spec.serve.traffic,
            seed=spec.serve.seed)
        trainer.serve_every = spec.serve.every
    return FederatedExperiment(spec, trainer, label)
