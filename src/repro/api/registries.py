"""String-keyed plugin registries — the extension points of the public API.

Every pluggable axis of the engine (aggregators, server optimizers, wire
transports, client samplers, execution backends) resolves names through one
of the registries below instead of an inline ``if/elif`` table, so a new
variant is one ``register_*`` call away from every entry point that speaks
strings: ``ExperimentSpec``, ``FedAvgTrainer``/``RoundEngine``,
``launch/train.py`` and the benchmarks (DESIGN.md §9).

A registry stores *factories*: callables that build the component from
keyword configuration. The per-kind factory signatures are documented on
the ``register_*`` aliases; all factories should accept ``**kw`` so new
configuration knobs never break old plugins.

Builtins register themselves when their defining module imports. Each
registry knows that module and imports it lazily on first lookup, so
``available()`` is complete no matter which of ``repro.api`` or
``repro.core.engine`` was imported first (and no import cycle forms: this
module imports nothing from the engine at module scope).

Unknown names raise ``KeyError`` with a did-you-mean suggestion::

    >>> get_aggregator("meen")
    KeyError: "unknown aggregator 'meen'. Did you mean 'mean'? ..."
"""
from __future__ import annotations

import difflib
import importlib
from typing import Any, Callable, Dict, Iterator, Optional, Tuple


class UnknownNameError(KeyError, ValueError):
    """Unknown registry name. Subclasses BOTH KeyError (mapping semantics)
    and ValueError (the engine's historical ``get_*`` contract), so callers
    catching either keep working."""

    def __str__(self) -> str:          # KeyError.__str__ repr()s the message
        return self.args[0] if self.args else ""


class Registry:
    """Name -> factory mapping with lazy builtin loading.

    ``register(name)`` works as a decorator or a direct call; registering an
    existing name overwrites it (latest wins — this is how users shadow a
    builtin with their own implementation).
    """

    def __init__(self, kind: str, builtins_module: Optional[str] = None):
        self.kind = kind
        self._entries: Dict[str, Callable[..., Any]] = {}
        self._builtins_module = builtins_module
        self._loaded = builtins_module is None
        self._loading = False

    # ------------------------------------------------------------------
    def _ensure_builtins(self) -> None:
        # _loaded flips only on success: a transient import failure surfaces
        # to every caller instead of poisoning the registry into reporting
        # builtin names as unknown; _loading guards re-entrant lookups while
        # the builtins module registers itself
        if self._loaded or self._loading:
            return
        self._loading = True
        try:
            importlib.import_module(self._builtins_module)
            self._loaded = True
        finally:
            self._loading = False

    # ------------------------------------------------------------------
    def register(self, name: str, factory: Optional[Callable] = None):
        """``register("x", f)`` or ``@register("x")`` above a factory."""
        if not isinstance(name, str) or not name:
            raise TypeError(f"{self.kind} name must be a non-empty string, "
                            f"got {name!r}")
        if factory is None:
            def deco(f):
                self._entries[name] = f
                return f
            return deco
        self._entries[name] = factory
        return factory

    def get(self, name: str) -> Callable[..., Any]:
        self._ensure_builtins()
        try:
            return self._entries[name]
        except KeyError:
            raise UnknownNameError(self._unknown_message(name)) from None

    def _unknown_message(self, name) -> str:
        avail = self.available()
        hint = ""
        close = difflib.get_close_matches(str(name), avail, n=1, cutoff=0.5)
        if close:
            hint = f" Did you mean {close[0]!r}?"
        return (f"unknown {self.kind} {name!r}.{hint} "
                f"Available: {', '.join(avail) or '(none registered)'}")

    def available(self) -> Tuple[str, ...]:
        self._ensure_builtins()
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        self._ensure_builtins()
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.available())

    def __repr__(self) -> str:
        return f"Registry({self.kind}: {', '.join(self.available())})"


# ---------------------------------------------------------------------------
# the registries (builtins live next to the protocols they implement)
# ---------------------------------------------------------------------------

#: factory(*, trim_fraction, **kw) -> Aggregator  ((N,...) stack, (N,) w -> (...))
AGGREGATOR_REGISTRY = Registry("aggregator", "repro.core.engine.aggregators")

#: factory(**kw) -> ServerOptimizer (init/step NamedTuple)
SERVER_OPTIMIZER_REGISTRY = Registry("server_optimizer",
                                     "repro.core.engine.server")

#: factory(*, topk_frac, **kw) -> Transport | None (None = identity wire path)
TRANSPORT_REGISTRY = Registry("transport", "repro.core.engine.transport")

#: factory(*, fed, **kw) -> ClientSampler (fed: configs.base.FedConfig)
SAMPLER_REGISTRY = Registry("sampler", "repro.core.engine.sampling")

#: factory(*, strategy, groups, **kw) -> ExecutionBackend
BACKEND_REGISTRY = Registry("backend", "repro.core.engine.backends")

#: AggregationPolicy axis (DESIGN.md §13): factory(loss_fn, init_params,
#: data, fed, runtime, *, eval_fn, backend, sampler, registry, program_key,
#: **kw) -> trainer ("sync" -> FedAvgTrainer, "async" -> AsyncBufferedEngine)
AGGREGATION_REGISTRY = Registry("aggregation",
                                "repro.core.engine.async_buffer")

#: factory(**kw) -> Callable[[staleness int], float] — the async buffer's
#: per-arrival contribution scale (DESIGN.md §13.3)
STALENESS_WEIGHT_REGISTRY = Registry("staleness_weight",
                                     "repro.core.engine.async_buffer")

#: factory(*, cfg, batch, prompt_len, seed, **kw) -> Callable[[tick int],
#: np.ndarray (batch, prompt_len) int prompt ids] — the ServingLoop's
#: deterministic query stream (DESIGN.md §14)
TRAFFIC_REGISTRY = Registry("traffic", "repro.core.serve.loop")

register_aggregator = AGGREGATOR_REGISTRY.register
register_server_optimizer = SERVER_OPTIMIZER_REGISTRY.register
register_transport = TRANSPORT_REGISTRY.register
register_sampler = SAMPLER_REGISTRY.register
register_backend = BACKEND_REGISTRY.register
register_aggregation = AGGREGATION_REGISTRY.register
register_staleness_weight = STALENESS_WEIGHT_REGISTRY.register
register_traffic = TRAFFIC_REGISTRY.register

REGISTRIES = {r.kind: r for r in (AGGREGATOR_REGISTRY,
                                  SERVER_OPTIMIZER_REGISTRY,
                                  TRANSPORT_REGISTRY, SAMPLER_REGISTRY,
                                  BACKEND_REGISTRY, AGGREGATION_REGISTRY,
                                  STALENESS_WEIGHT_REGISTRY,
                                  TRAFFIC_REGISTRY)}
