"""Sweep grids over ``ExperimentSpec`` + program fingerprints (DESIGN.md §12).

A sweep assignment is the ``with_overrides`` dotted-path syntax with a
comma list on the right-hand side::

    expand_sweep("fed.k0=2,4,8", "transport.name=int8,topk")

expands the cross product (here 3 x 2 = 6 points) into fully-validated
specs, reusing ``with_overrides``'s JSON-first value coercion per element.
Unknown dotted paths / uncoercible values are aggregated into one loud
``SpecValidationError`` — a typo'd sweep axis never silently collapses the
grid.

``spec_program_key(spec)`` is the other half of the fleet contract: a
hashable fingerprint of every spec field that shapes the *traced program*
(model/task, aggregator/server, transport + downlink config, backend
placement, chunking) while excluding everything that only shows up in the
input *signature* (k0/eta0/rounds/seeds/batch sizes — those are array
shapes/values). Two sweep points share AOT executables in the fleet's
``ExecutableRegistry`` exactly when their program keys AND bucket input
signatures coincide.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.api.spec import (ExperimentSpec, SpecValidationError,
                            _parse_scalar)


def parse_sweep(assignments: Sequence[str]) -> "List[Tuple[str, List[Any]]]":
    """``["fed.k0=2,4,8"] -> [("fed.k0", [2, 4, 8])]`` — split each sweep
    assignment into its dotted path and value list (JSON-parsed per
    element; single values become one-element axes). Syntax errors
    aggregate into one ``SpecValidationError``."""
    errors: List[str] = []
    axes: List[Tuple[str, List[Any]]] = []
    for a in assignments:
        if "=" not in a:
            errors.append(f"{a!r}: sweep assignment must look like "
                          f"'section.field=v1,v2,...'")
            continue
        path, _, raw = a.partition("=")
        path = path.strip()
        if len(path.split(".")) != 2:
            errors.append(f"{path!r}: sweep path must be 'section.field' "
                          f"(two components)")
            continue
        values = [_parse_scalar(part) for part in raw.split(",")]
        if any(isinstance(v, str) and not v for v in values):
            errors.append(f"{path}: empty value in sweep list {raw!r}")
            continue
        axes.append((path, values))
    if errors:
        raise SpecValidationError(errors)
    return axes


def sweep_grid(assignments: Sequence[str]
               ) -> List[Tuple[Tuple[str, ...], str]]:
    """Cross product of the sweep axes.

    Returns ``[(override_tuple, label), ...]`` where each
    ``override_tuple`` is a tuple of single-value ``section.field=value``
    assignments (ready for ``with_overrides``) and ``label`` is the short
    human/CSV name (``k0=2|uplink=int8``: last path component + value,
    axes joined by '|')."""
    points: List[Tuple[Tuple[str, ...], str]] = [((), "")]
    for path, values in parse_sweep(assignments):
        fld = path.split(".")[1]
        nxt = []
        for overrides, label in points:
            for v in values:
                ov = f"{path}={_unparse(v)}"
                lab = f"{fld}={_unparse(v)}"
                nxt.append((overrides + (ov,),
                            f"{label}|{lab}" if label else lab))
        points = nxt
    return points


def _unparse(value: Any) -> str:
    """Value back to override-text form (round-trips through json/_coerce)."""
    if isinstance(value, str):
        return value
    import json
    return json.dumps(value)


@dataclass(frozen=True)
class SweepPoint:
    """One expanded grid point: the validated spec plus its provenance."""
    label: str                     # "k0=2|uplink=int8" (CSV/leaderboard id)
    overrides: Tuple[str, ...]     # single-value with_overrides assignments
    spec: ExperimentSpec


def expand_sweep(*assignments: str,
                 base: Optional[ExperimentSpec] = None) -> List[SweepPoint]:
    """Expand sweep assignments over ``base`` (default ``ExperimentSpec()``)
    into validated ``SweepPoint``s — the cross product of all comma lists.

    Every error across every point (unknown dotted path, uncoercible
    value, spec-level validation failure) is aggregated into ONE
    ``SpecValidationError`` so a bad grid fails loudly up front, before
    any point starts compiling."""
    base = base if base is not None else ExperimentSpec()
    grid = sweep_grid(assignments)
    errors: List[str] = []
    points: List[SweepPoint] = []
    for overrides, label in grid:
        try:
            spec = base.with_overrides(*overrides).validate()
        except SpecValidationError as e:
            where = label or "<base>"
            errors.extend(f"[{where}] {msg}" for msg in e.errors)
            continue
        points.append(SweepPoint(label=label or "base",
                                 overrides=overrides, spec=spec))
    if errors:
        # dedupe while keeping order: the same bad axis value appears in
        # every cross-product point it touches
        seen: Dict[str, None] = {}
        for msg in errors:
            seen.setdefault(msg)
        raise SpecValidationError(list(seen))
    return points


def spec_program_key(spec: ExperimentSpec) -> Tuple:
    """Hashable fingerprint of the spec fields that shape the traced
    program (NOT the input signature).

    Included: the model/task identity (decides loss_fn + param tree), the
    aggregation program (aggregator/trim/server/server_lr — python
    constants baked into the trace), the full transport + downlink config,
    the sampler name (fixed cohorts move EF state to per-client slots,
    changing the program), chunking, and the backend placement section.
    Excluded on purpose: k0/eta0/rounds/seeds/batch sizes/cohort sizes —
    those live in the bucket input signature, which is the other half of
    the registry key.

    Mesh fleets must extend this with the slice's device ids (executables
    are bound to devices); ``launch.fleet`` does."""
    m, d, f = spec.model, spec.data, spec.fed
    t, b, s = spec.transport, spec.backend, spec.sampler
    model_id = (("paper", d.task) if d.kind == "paper"
                else ("lm", m.arch, m.reduced, m.moe_path))
    return (
        "program", model_id,
        ("agg", f.aggregator, f.trim_fraction, f.server_optimizer,
         f.server_lr),
        ("transport", t.name, t.topk_frac, t.downlink, t.ref_store),
        ("sampler", s.name),
        ("chunk", f.cohort_chunk),
        ("backend", b.name, b.strategy, b.groups, b.reduce),
    )
