"""repro.api — the repo's public, declarative experiment API (DESIGN.md §9).

    from repro.api import ExperimentSpec, build

    spec = ExperimentSpec.load("examples/specs/local-int8-decayK.json")
    spec = spec.with_overrides("fed.rounds=50", "transport.name=topk")
    exp = build(spec)            # -> FederatedExperiment
    history = exp.run()
    exp.save("/tmp/ckpt")        # spec embedded: restore() rebuilds exactly

Extension points are string-keyed registries (``register_aggregator``,
``register_transport``, ``register_server_optimizer``, ``register_sampler``,
``register_backend``) — everything that resolves components by name
(``ExperimentSpec``, ``FedAvgTrainer``, ``launch/train.py``) looks the name
up there, so a registered plugin is usable everywhere at once.

Attribute access is lazy (PEP 562): importing ``repro.api`` pulls in no jax
or engine modules until a name is actually used.
"""
from __future__ import annotations

_SPEC_NAMES = ("ExperimentSpec", "ModelSpec", "DataSpec", "FedSpec",
               "SamplerSpec", "TransportSpec", "BackendSpec", "RuntimeSpec",
               "SpecValidationError")
_EXPERIMENT_NAMES = ("FederatedExperiment", "build")
_SWEEP_NAMES = ("SweepPoint", "expand_sweep", "sweep_grid", "parse_sweep",
                "spec_program_key")
_REGISTRY_NAMES = ("Registry", "REGISTRIES", "UnknownNameError",
                   "AGGREGATOR_REGISTRY", "SERVER_OPTIMIZER_REGISTRY",
                   "TRANSPORT_REGISTRY", "SAMPLER_REGISTRY",
                   "BACKEND_REGISTRY",
                   "register_aggregator", "register_server_optimizer",
                   "register_transport", "register_sampler",
                   "register_backend")

__all__ = list(_SPEC_NAMES + _EXPERIMENT_NAMES + _SWEEP_NAMES
               + _REGISTRY_NAMES)


def __getattr__(name):
    if name in _SPEC_NAMES:
        from repro.api import spec as _m
    elif name in _EXPERIMENT_NAMES:
        from repro.api import experiment as _m
    elif name in _SWEEP_NAMES:
        from repro.api import sweep as _m
    elif name in _REGISTRY_NAMES:
        from repro.api import registries as _m
    else:
        raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
    return getattr(_m, name)


def __dir__():
    return sorted(__all__)
