"""ExperimentSpec — the frozen, serializable description of one experiment.

One JSON artifact pins everything a run needs: model, data, federated
schedule, sampler, transport, backend and runtime model. ``build(spec)``
(``repro.api.experiment``) turns it into a ready ``FederatedExperiment``;
the spec rides inside every checkpoint so ``restore`` rebuilds the exact
trainer (DESIGN.md §9).

Contracts:

  * ``from_json(spec.to_json()) == spec`` — exact dataclass round-trip.
  * ``validate()`` raises one ``SpecValidationError`` carrying ALL
    problems (dotted paths included), not just the first.
  * ``with_overrides("fed.k0=4", "transport.name=int8")`` — dotted-path
    overrides with field-type coercion; values parse as JSON first
    (``fed.cohort=[0,1,2]`` works) and fall back to raw strings.
  * unknown JSON keys are aggregated errors, never silently dropped —
    schema drift in saved specs is loud.
"""
from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class SpecValidationError(ValueError):
    """All spec problems at once: ``errors`` is a list of 'path: message'."""

    def __init__(self, errors: List[str]):
        self.errors = list(errors)
        msg = "\n  - ".join(self.errors)
        super().__init__(f"invalid ExperimentSpec ({len(self.errors)} "
                         f"error(s)):\n  - {msg}")


# ---------------------------------------------------------------------------
# leaf specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelSpec:
    """Model selection; used when ``data.kind == 'lm'`` (the paper-task data
    kinds carry their own small models)."""
    arch: str = "qwen1.5-0.5b"     # configs.ARCHS key
    reduced: bool = True           # CPU-scale same-family variant
    moe_path: str = "dense"        # MoE dispatch path for loss_fn


@dataclass(frozen=True)
class DataSpec:
    kind: str = "lm"               # 'lm' (synthetic LM tokens) | 'paper'
    task: str = ""                 # paper task name for kind='paper'
    clients: int = 24              # total client population
    samples_per_client: int = 64
    seq_len: int = 64              # kind='lm' sequence length
    seed: int = 0                  # data-generation rng (not the run seed)


@dataclass(frozen=True)
class FedSpec:
    """The paper's algorithm knobs (mirrors ``configs.base.FedConfig``)."""
    rounds: int = 100
    clients_per_round: int = 16
    k0: int = 16
    eta0: float = 0.1
    batch_size: int = 32
    k_schedule: str = "fixed"
    eta_schedule: str = "fixed"
    k_quantize: bool = False
    k_grid0: Optional[int] = None  # explicit quantize_k grid anchor (None =
                                   # k0); fleet sweeps pin one anchor so
                                   # points with different k0 share bucket
                                   # shapes + executables (DESIGN.md §12)
    k_min: int = 1
    loss_window: int = 100
    plateau_patience: int = 50
    step_decay_factor: float = 10.0
    server_optimizer: str = "avg"
    server_lr: float = 1.0
    aggregator: str = "mean"
    trim_fraction: float = 0.1
    bucket_rounds: int = 8
    feedback_bucket_rounds: int = 1
    prefetch: bool = True
    eval_every: int = 0            # 0 = no evaluation pass
    cohort_chunk: Optional[int] = None   # streaming slab size C (§11);
                                         # None = dense vmapped cohort
    # --- async buffered aggregation (DESIGN.md §13) ---
    aggregation: str = "sync"            # sync (round-synchronous, default,
                                         # program-identical) | async
                                         # (FedBuff-style buffered folding)
    buffer_size: Optional[int] = None    # async: apply after this many
                                         # arrivals (None = clients_per_round)
    staleness_weight: str = "constant"   # async: constant | inv | poly
    max_staleness: Optional[int] = None  # async: drop arrivals staler than
                                         # this many versions (None = keep)
    seed: int = 0


@dataclass(frozen=True)
class SamplerSpec:
    name: str = "uniform"          # uniform|weighted|fixed_cohort|
                                   # availability|population (§11)
    availability: float = 0.9      # Bernoulli online prob (availability);
                                   # peak diurnal prob (population)
    cohort: Optional[Tuple[int, ...]] = None   # fixed_cohort membership
    population: int = 0            # population sampler: virtual client-id
                                   # space (10^6+); 0 = data.clients
    day_rounds: int = 24           # population: diurnal period in rounds
    base_availability: float = 0.05  # population: trough diurnal prob


@dataclass(frozen=True)
class TransportSpec:
    name: str = "none"             # none|int8|int8x2|topk (DESIGN.md §8)
    topk_frac: float = 0.1
    downlink: str = "none"         # broadcast codec: same names plus
                                   # "adaptive" (§8.6, §10)
    ref_store: str = "f32"         # server-held downlink ref/residual
                                   # store: f32 | q8 (§10.3)


@dataclass(frozen=True)
class BackendSpec:
    name: str = "local"            # local|mesh (DESIGN.md §7)
    strategy: str = "parallel"     # mesh client fan-out
    groups: int = 1                # sequential-strategy client groups
    reduce: str = "flat"           # flat | grouped two-tier psum (§11)


@dataclass(frozen=True)
class ServeSpec:
    """Serve-while-training (DESIGN.md §14): hot-swap the global model
    into a live decode service between rounds / buffer applies."""
    every: int = 0                 # tick the serving loop every N rounds
                                   # (sync) / buffer applies (async);
                                   # 0 = no serving
    qps: float = 0.0               # sustained decode queries/sec the server
                                   # answers alongside training (runtime
                                   # cost model only; 0 = free serving)
    query_ms: float = 1.0          # modelled per-query decode seconds*1e3;
                                   # rho = qps * query_ms/1e3 must be < 1
    batch: int = 2                 # traffic replay batch per tick
    prompt_len: int = 4
    tokens: int = 8                # greedy-decoded tokens per query
    traffic: str = "synthetic"     # TRAFFIC_REGISTRY stream name
    seed: int = 0                  # traffic stream seed


@dataclass(frozen=True)
class RuntimeSpec:
    """Eq. 3-5 constants (mirrors ``configs.base.RuntimeModelConfig``)."""
    download_mbps: float = 20.0
    upload_mbps: float = 5.0
    beta_seconds: float = 0.1
    bytes_per_param: int = 4
    heterogeneity: float = 0.0     # lognormal straggler sigma (0 = Eq. 5)


# ---------------------------------------------------------------------------
# the tree
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExperimentSpec:
    model: ModelSpec = field(default_factory=ModelSpec)
    data: DataSpec = field(default_factory=DataSpec)
    fed: FedSpec = field(default_factory=FedSpec)
    sampler: SamplerSpec = field(default_factory=SamplerSpec)
    transport: TransportSpec = field(default_factory=TransportSpec)
    backend: BackendSpec = field(default_factory=BackendSpec)
    runtime: RuntimeSpec = field(default_factory=RuntimeSpec)
    serve: ServeSpec = field(default_factory=ServeSpec)

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.as_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        errors: List[str] = []
        kwargs: Dict[str, Any] = {}
        sections = {f.name: f for f in dataclasses.fields(cls)}
        for key in d:
            if key not in sections:
                errors.append(f"{key}: unknown section (expected one of "
                              f"{sorted(sections)})")
        for name, f in sections.items():
            sub = d.get(name)
            if sub is None:
                continue
            if not isinstance(sub, dict):
                errors.append(f"{name}: expected an object, got "
                              f"{type(sub).__name__}")
                continue
            sub_cls = f.default_factory
            sub_fields = {sf.name: sf for sf in dataclasses.fields(sub_cls)}
            sub_kwargs = {}
            for k, v in sub.items():
                if k not in sub_fields:
                    errors.append(f"{name}.{k}: unknown field (expected one "
                                  f"of {sorted(sub_fields)})")
                    continue
                try:
                    sub_kwargs[k] = _coerce(v, sub_fields[k].type,
                                            f"{name}.{k}")
                except ValueError as e:
                    errors.append(str(e))
            if not errors:
                kwargs[name] = sub_cls(**sub_kwargs)
        if errors:
            raise SpecValidationError(errors)
        return cls(**kwargs)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # ------------------------------------------------------------------
    # dotted-path overrides
    # ------------------------------------------------------------------
    def with_overrides(self, *assignments: str) -> "ExperimentSpec":
        """``spec.with_overrides("fed.k0=4", "transport.name=int8")``.

        Each assignment is ``section.field=value``; values are parsed as
        JSON when possible (numbers, booleans, null, lists) and coerced to
        the field's declared type. All bad assignments are reported in one
        ``SpecValidationError``."""
        errors: List[str] = []
        updates: Dict[str, Dict[str, Any]] = {}
        sections = {f.name: f for f in dataclasses.fields(self)}
        for a in assignments:
            if "=" not in a:
                errors.append(f"{a!r}: override must look like "
                              f"'section.field=value'")
                continue
            path, _, raw = a.partition("=")
            parts = path.strip().split(".")
            if len(parts) != 2:
                errors.append(f"{path!r}: override path must be "
                              f"'section.field' (two components)")
                continue
            sec, fld = parts
            if sec not in sections:
                errors.append(f"{sec!r}: unknown section (expected one of "
                              f"{sorted(sections)})")
                continue
            sub = getattr(self, sec)
            sub_fields = {sf.name: sf for sf in dataclasses.fields(sub)}
            if fld not in sub_fields:
                errors.append(f"{sec}.{fld}: unknown field (expected one of "
                              f"{sorted(sub_fields)})")
                continue
            val = _parse_override_value(raw)
            try:
                updates.setdefault(sec, {})[fld] = _coerce(
                    val, sub_fields[fld].type, f"{sec}.{fld}")
            except ValueError as e:
                msg = str(e)
                if isinstance(val, list) and "," in raw and \
                        not raw.strip().startswith("["):
                    msg += (" — a comma list on a scalar field is sweep "
                            "syntax: expand it into one spec per value "
                            "with repro.api.sweep.expand_sweep(...) or "
                            "launch with --sweep")
                errors.append(msg)
        if errors:
            raise SpecValidationError(errors)
        new_sections = {sec: dataclasses.replace(getattr(self, sec), **kw)
                        for sec, kw in updates.items()}
        return dataclasses.replace(self, **new_sections)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "ExperimentSpec":
        """Raise ``SpecValidationError`` with EVERY problem, or return self."""
        errors: List[str] = []
        m, d, f = self.model, self.data, self.fed
        s, t, b, r = self.sampler, self.transport, self.backend, self.runtime
        sv = self.serve

        if d.kind not in ("lm", "paper"):
            errors.append(f"data.kind: {d.kind!r} not in ('lm', 'paper')")
        elif d.kind == "paper":
            from repro.configs.paper_tasks import PAPER_TASKS
            if d.task not in PAPER_TASKS:
                errors.append(f"data.task: {d.task!r} not in "
                              f"{sorted(PAPER_TASKS)}")
        else:
            from repro.configs import ARCHS
            if m.arch not in ARCHS:
                errors.append(f"model.arch: {m.arch!r} not a known "
                              f"architecture (see configs.ARCHS)")
        for name, v in (("data.clients", d.clients),
                        ("data.samples_per_client", d.samples_per_client),
                        ("data.seq_len", d.seq_len),
                        ("fed.rounds", f.rounds),
                        ("fed.clients_per_round", f.clients_per_round),
                        ("fed.k0", f.k0), ("fed.batch_size", f.batch_size),
                        ("fed.k_min", f.k_min),
                        ("fed.bucket_rounds", f.bucket_rounds),
                        ("fed.feedback_bucket_rounds",
                         f.feedback_bucket_rounds),
                        ("backend.groups", b.groups)):
            if v < 1:
                errors.append(f"{name}: must be >= 1, got {v}")
        if f.clients_per_round > d.clients:
            errors.append(f"fed.clients_per_round: {f.clients_per_round} "
                          f"exceeds data.clients ({d.clients})")
        if f.eta0 <= 0:
            errors.append(f"fed.eta0: must be > 0, got {f.eta0}")
        if f.eval_every < 0:
            errors.append(f"fed.eval_every: must be >= 0, got {f.eval_every}")

        if f.k_grid0 is not None:
            if f.k_grid0 < 1:
                errors.append(f"fed.k_grid0: must be >= 1, got {f.k_grid0}")
            elif not f.k_quantize:
                errors.append("fed.k_grid0: a pinned quantize-grid anchor "
                              "only applies when fed.k_quantize=true")

        from repro.core.schedules import ETA_SCHEDULES, K_SCHEDULES
        if f.k_schedule not in K_SCHEDULES:
            errors.append(f"fed.k_schedule: {f.k_schedule!r} not in "
                          f"{K_SCHEDULES}")
        if f.eta_schedule not in ETA_SCHEDULES:
            errors.append(f"fed.eta_schedule: {f.eta_schedule!r} not in "
                          f"{ETA_SCHEDULES}")

        from repro.api.registries import (AGGREGATOR_REGISTRY,
                                          BACKEND_REGISTRY, SAMPLER_REGISTRY,
                                          SERVER_OPTIMIZER_REGISTRY,
                                          TRANSPORT_REGISTRY)
        for reg, path, name in (
                (AGGREGATOR_REGISTRY, "fed.aggregator", f.aggregator),
                (SERVER_OPTIMIZER_REGISTRY, "fed.server_optimizer",
                 f.server_optimizer),
                (TRANSPORT_REGISTRY, "transport.name", t.name),
                (TRANSPORT_REGISTRY, "transport.downlink", t.downlink),
                (SAMPLER_REGISTRY, "sampler.name", s.name),
                (BACKEND_REGISTRY, "backend.name", b.name)):
            if name not in reg:
                errors.append(f"{path}: {reg._unknown_message(name)}")

        from repro.core.engine.backends.base import LINEAR_AGGREGATORS
        if (t.name in TRANSPORT_REGISTRY and t.name != "none"
                and f.aggregator not in LINEAR_AGGREGATORS):
            errors.append(f"transport.name: compressed codec {t.name!r} "
                          f"requires a linear aggregator "
                          f"{LINEAR_AGGREGATORS}, got {f.aggregator!r}")
        if not 0.0 < t.topk_frac <= 1.0:
            errors.append(f"transport.topk_frac: must be in (0, 1], got "
                          f"{t.topk_frac}")
        if t.name == "adaptive":
            errors.append("transport.name: 'adaptive' is a downlink-only "
                          "codec — set transport.downlink='adaptive' "
                          "instead")
        if t.ref_store not in ("f32", "q8"):
            errors.append(f"transport.ref_store: must be 'f32' or 'q8', "
                          f"got {t.ref_store!r}")
        elif t.ref_store != "f32" and t.downlink == "none":
            errors.append("transport.ref_store: a quantised ref store "
                          "requires a downlink codec "
                          "(transport.downlink != 'none')")
        if not 0.0 < s.availability <= 1.0:
            errors.append(f"sampler.availability: must be in (0, 1], got "
                          f"{s.availability}")
        if s.name == "availability" and f.aggregator not in LINEAR_AGGREGATORS:
            errors.append("sampler.name: availability shortfall padding "
                          "needs a weight-respecting (linear) aggregator, "
                          f"got {f.aggregator!r}")
        if s.cohort is not None:
            if s.name != "fixed_cohort":
                errors.append("sampler.cohort: only meaningful for "
                              f"sampler.name='fixed_cohort', got {s.name!r}")
            elif len(s.cohort) != f.clients_per_round:
                errors.append(f"sampler.cohort: {len(s.cohort)} clients, "
                              f"fed.clients_per_round is "
                              f"{f.clients_per_round}")
            elif any(not 0 <= c < d.clients for c in s.cohort):
                errors.append(f"sampler.cohort: ids must be in "
                              f"[0, {d.clients})")
        if s.name == "population":
            if f.aggregator not in LINEAR_AGGREGATORS:
                errors.append("sampler.name: population sampling weights "
                              "the diurnal cohort — needs a linear "
                              f"aggregator, got {f.aggregator!r}")
            pop = s.population if s.population else d.clients
            if f.clients_per_round > pop:
                errors.append(f"fed.clients_per_round: "
                              f"{f.clients_per_round} exceeds the "
                              f"population ({pop})")
        if s.population < 0:
            errors.append(f"sampler.population: must be >= 0, got "
                          f"{s.population}")
        elif s.population and s.name != "population":
            errors.append("sampler.population: only meaningful for "
                          f"sampler.name='population', got {s.name!r}")
        if s.day_rounds < 1:
            errors.append(f"sampler.day_rounds: must be >= 1, got "
                          f"{s.day_rounds}")
        if not 0.0 < s.base_availability <= 1.0:
            errors.append(f"sampler.base_availability: must be in (0, 1], "
                          f"got {s.base_availability}")
        if f.cohort_chunk is not None:
            if f.cohort_chunk < 1:
                errors.append(f"fed.cohort_chunk: must be >= 1, got "
                              f"{f.cohort_chunk}")
            if f.aggregator not in LINEAR_AGGREGATORS:
                errors.append("fed.cohort_chunk: streaming slabs fold into "
                              "a running weighted sum — robust aggregators "
                              f"(got {f.aggregator!r}) need the whole "
                              f"cohort stack; use {LINEAR_AGGREGATORS} or "
                              "drop cohort_chunk")
            if t.downlink != "none":
                errors.append("fed.cohort_chunk: chunked streaming rounds "
                              "do not compose with a downlink codec yet "
                              "(the per-slab broadcast would re-encode per "
                              "slab) — set transport.downlink='none'")
            if b.name == "mesh" and b.strategy == "sequential":
                errors.append("fed.cohort_chunk: the mesh sequential "
                              "strategy already streams clients through a "
                              "scan — cohort_chunk only applies to the "
                              "parallel (vmapped) cohort")
        from repro.api.registries import (AGGREGATION_REGISTRY,
                                          STALENESS_WEIGHT_REGISTRY)
        if f.aggregation not in AGGREGATION_REGISTRY:
            errors.append(f"fed.aggregation: "
                          f"{AGGREGATION_REGISTRY._unknown_message(f.aggregation)}")
        if f.staleness_weight not in STALENESS_WEIGHT_REGISTRY:
            errors.append(f"fed.staleness_weight: "
                          f"{STALENESS_WEIGHT_REGISTRY._unknown_message(f.staleness_weight)}")
        if f.aggregation == "async":
            if f.aggregator not in LINEAR_AGGREGATORS:
                errors.append("fed.aggregation: async buffered folding is a "
                              "streaming weighted sum — robust aggregators "
                              f"(got {f.aggregator!r}) need the whole cohort "
                              f"stack at once; use {LINEAR_AGGREGATORS} or "
                              "fed.aggregation='sync'")
            if f.cohort_chunk is not None:
                errors.append("fed.cohort_chunk: chunked streaming cohorts "
                              "are a round-synchronous execution shape — the "
                              "async engine already streams arrivals one at "
                              "a time; drop fed.cohort_chunk")
            if b.name == "mesh" and b.strategy == "sequential":
                errors.append("backend.strategy: the mesh sequential scan "
                              "folds a whole synchronous cohort — async "
                              "dispatch groups are ragged; use "
                              "backend.strategy='parallel'")
            if t.downlink != "none":
                errors.append("transport.downlink: async clients start from "
                              "skewed global versions, so the single "
                              "broadcast-reference state machine cannot "
                              "encode one delta for all of them yet — set "
                              "transport.downlink='none'")
            if s.name == "fixed_cohort":
                errors.append("sampler.name: 'fixed_cohort' pins one client "
                              "per slot, but async redispatches ragged "
                              "groups of freed slots — use 'uniform' or "
                              "'weighted'")
            if f.buffer_size is not None:
                if f.buffer_size < 1:
                    errors.append(f"fed.buffer_size: must be >= 1, got "
                                  f"{f.buffer_size}")
                elif f.buffer_size > f.clients_per_round:
                    errors.append(f"fed.buffer_size: {f.buffer_size} exceeds "
                                  f"fed.clients_per_round "
                                  f"({f.clients_per_round}) — the buffer "
                                  f"can never fill past the in-flight "
                                  f"cohort; lower fed.buffer_size or raise "
                                  f"fed.clients_per_round")
            if f.max_staleness is not None and f.max_staleness < 0:
                errors.append(f"fed.max_staleness: must be >= 0, got "
                              f"{f.max_staleness}")
        else:
            for name, v in (("fed.buffer_size", f.buffer_size),
                            ("fed.max_staleness", f.max_staleness)):
                if v is not None:
                    errors.append(f"{name}: only meaningful for "
                                  f"fed.aggregation='async', got "
                                  f"aggregation={f.aggregation!r}")
            if f.staleness_weight != "constant":
                errors.append(f"fed.staleness_weight: "
                              f"{f.staleness_weight!r} only applies to "
                              f"fed.aggregation='async' (sync rounds have "
                              f"staleness 0 by construction)")
        if b.strategy not in ("parallel", "sequential"):
            errors.append(f"backend.strategy: {b.strategy!r} not in "
                          f"('parallel', 'sequential')")
        if b.reduce not in ("flat", "grouped"):
            errors.append(f"backend.reduce: {b.reduce!r} not in "
                          f"('flat', 'grouped')")
        for name, v in (("runtime.download_mbps", r.download_mbps),
                        ("runtime.upload_mbps", r.upload_mbps),
                        ("runtime.beta_seconds", r.beta_seconds)):
            if v <= 0:
                errors.append(f"{name}: must be > 0, got {v}")
        if sv.every < 0:
            errors.append(f"serve.every: must be >= 0, got {sv.every}")
        if sv.qps < 0:
            errors.append(f"serve.qps: must be >= 0, got {sv.qps}")
        if sv.query_ms <= 0:
            errors.append(f"serve.query_ms: must be > 0, got {sv.query_ms}")
        for name, v in (("serve.batch", sv.batch),
                        ("serve.prompt_len", sv.prompt_len),
                        ("serve.tokens", sv.tokens)):
            if v < 1:
                errors.append(f"{name}: must be >= 1, got {v}")
        if sv.every > 0 and d.kind != "lm":
            errors.append("serve.every: the serving loop decodes through "
                          "the LM cache path — only data.kind='lm' runs "
                          f"can serve, got {d.kind!r}")
        if sv.qps > 0 and sv.every == 0:
            errors.append("serve.qps: a serve load on the runtime model "
                          "without a serving loop (serve.every=0) models a "
                          "service that never answers — set serve.every >= 1")
        rho = sv.qps * sv.query_ms / 1e3
        if rho >= 1.0:
            errors.append(f"serve.qps: utilisation rho = qps * query_ms/1e3 "
                          f"= {rho:.3f} >= 1 — the server spends every "
                          f"second decoding and training never progresses; "
                          f"lower serve.qps or serve.query_ms")
        from repro.api.registries import TRAFFIC_REGISTRY
        if sv.traffic not in TRAFFIC_REGISTRY:
            errors.append(f"serve.traffic: "
                          f"{TRAFFIC_REGISTRY._unknown_message(sv.traffic)}")
        if errors:
            raise SpecValidationError(errors)
        return self


# ---------------------------------------------------------------------------
# type coercion for json / override values
# ---------------------------------------------------------------------------

def _parse_override_value(raw: str) -> Any:
    """Parse an override's right-hand side: JSON first, then a bare comma
    list (``sampler.cohort=0,1,2`` == ``[0,1,2]``), then a raw string. The
    comma form is what ``--sweep`` grids are written in; on a tuple field it
    coerces directly, on a scalar field the caller reports it as sweep
    syntax."""
    try:
        return json.loads(raw)
    except (json.JSONDecodeError, ValueError):
        pass
    text = raw.strip()
    if "," in text and not text.startswith(("[", "{")):
        return [_parse_scalar(part) for part in text.split(",")]
    return text


def _parse_scalar(text: str) -> Any:
    try:
        return json.loads(text)
    except (json.JSONDecodeError, ValueError):
        return text.strip()


def _coerce(value: Any, ftype: Any, path: str) -> Any:
    """Coerce a parsed JSON value to a dataclass field's declared type."""
    if isinstance(ftype, str):                 # from __future__ annotations
        ftype = {"int": int, "float": float, "bool": bool, "str": str,
                 "Optional[int]": Optional[int],
                 "Optional[Tuple[int, ...]]": Optional[Tuple[int, ...]],
                 }.get(ftype, ftype)
    origin = typing.get_origin(ftype)
    if origin is typing.Union:                 # Optional[...]
        if value is None:
            return None
        inner = [a for a in typing.get_args(ftype) if a is not type(None)]
        return _coerce(value, inner[0], path)
    if origin in (tuple, Tuple):
        if not isinstance(value, (list, tuple)):
            raise ValueError(f"{path}: expected a list, got {value!r}")
        args = typing.get_args(ftype)
        elem = args[0] if args else None
        return tuple(_coerce(v, elem, path) for v in value)
    if ftype is bool:
        if isinstance(value, bool):
            return value
        if isinstance(value, str) and value.lower() in ("true", "false"):
            return value.lower() == "true"
        raise ValueError(f"{path}: expected a boolean, got {value!r}")
    if ftype is int:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ValueError(f"{path}: expected an integer, got {value!r}")
        if isinstance(value, float) and not value.is_integer():
            raise ValueError(f"{path}: expected an integer, got {value!r}")
        return int(value)
    if ftype is float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ValueError(f"{path}: expected a number, got {value!r}")
        return float(value)
    if ftype is str:
        if not isinstance(value, str):
            raise ValueError(f"{path}: expected a string, got {value!r}")
        return value
    return value
