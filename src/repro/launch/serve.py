"""Batched serving launcher: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \\
        --batch 4 --prompt-len 16 --tokens 32 [--checkpoint /tmp/ckpt]

CPU runs the reduced config; the mesh-level serve_step (sharded caches,
head-dim/kv-head sharding rules) is exercised by repro.launch.dryrun for
the decode_32k / long_500k shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.configs import ARCHS, get_arch
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="zamba2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = registry.init(rng, cfg)
    if args.checkpoint:
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            params)
        params, meta = load_checkpoint(args.checkpoint, like)
        print(f"[serve] restored checkpoint ({meta})")

    B = args.batch
    max_seq = args.prompt_len + args.tokens
    if cfg.arch_type == "audio":
        audio = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        cache = registry.init_cache(params, cfg, B, max_seq, audio_embeds=audio)
    else:
        cache = registry.init_cache(params, cfg, B, max_seq)
    step = jax.jit(registry.decode_fn(cfg, moe_path="dense"))

    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                0, cfg.vocab_size)
    for pos in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, pos], jnp.int32(pos))

    tok = jnp.argmax(logits, axis=-1)
    t0 = time.perf_counter()
    generated = []
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok,
                             jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)
        generated.append(tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name} ({cfg.arch_type}): batch={B}, "
          f"{args.tokens} tokens/seq, {B * args.tokens / dt:.1f} tok/s (CPU)")
    print(f"[serve] ids[0] = {jnp.stack(generated, 1)[0].tolist()}")


if __name__ == "__main__":
    main()
