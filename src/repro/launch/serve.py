"""Batched serving launcher: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \\
        --batch 4 --prompt-len 16 --tokens 32 [--checkpoint /tmp/ckpt]

Built on the ``GlobalModelStore`` + ``ServingLoop`` serving stack
(DESIGN.md §14). Trainer checkpoints embed their ``ExperimentSpec``, so the
model is rebuilt FROM THE SPEC inside the checkpoint — arch, reduced flag
and init seed are never trusted from flags; an explicitly conflicting
``--arch`` errors loudly instead of silently decoding through the wrong
architecture. Legacy bare-params checkpoints (no spec in meta) fall back to
``--arch``.

CPU runs the reduced config; the mesh-level serve_step (sharded caches,
head-dim/kv-head sharding rules) is exercised by repro.launch.dryrun for
the decode_32k / long_500k shapes.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.configs import ARCHS, get_arch
from repro.models import registry


def _shapes_like(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)


def load_serving_params(path: str, arch_arg=None, seed: int = 0):
    """(cfg, params) from a checkpoint directory.

    Spec-embedded checkpoints (everything ``FederatedExperiment.save``
    writes) rebuild the model from the spec; the stored tree keeps params
    under the ``params/`` prefix next to server/transport/downlink state.
    Legacy checkpoints without a spec fall back to ``arch_arg`` and accept
    either bare-params or prefixed layouts."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if "spec" in meta:
        from repro.api import ExperimentSpec
        spec = ExperimentSpec.from_dict(meta["spec"])
        if spec.data.kind != "lm":
            raise SystemExit(
                f"[serve] checkpoint {path!r} trained data.kind="
                f"{spec.data.kind!r} (a paper-task model, not an LM) — "
                f"there is no decode path to serve it")
        if arch_arg is not None and arch_arg != spec.model.arch:
            raise SystemExit(
                f"[serve] --arch {arch_arg!r} conflicts with the "
                f"checkpoint's embedded spec (model.arch="
                f"{spec.model.arch!r}, reduced={spec.model.reduced}); "
                f"drop --arch — the model is rebuilt from the spec")
        cfg = get_arch(spec.model.arch)
        if spec.model.reduced:
            cfg = cfg.reduced()
        template = registry.init(jax.random.PRNGKey(spec.fed.seed), cfg)
        tree, _ = load_checkpoint(path, {"params": _shapes_like(template)})
        print(f"[serve] rebuilt {spec.model.arch} "
              f"(reduced={spec.model.reduced}) from the checkpoint's "
              f"embedded spec, round {meta.get('completed_rounds', '?')}")
        return cfg, tree["params"]
    # legacy bare-params checkpoint: the arch must come from the flag
    cfg = get_arch(arch_arg or "zamba2-7b").reduced()
    like = _shapes_like(registry.init(jax.random.PRNGKey(seed), cfg))
    try:
        params, _ = load_checkpoint(path, like)
    except KeyError:
        # trainer layout without a spec: params under the "params/" prefix
        params = load_checkpoint(path, {"params": like})[0]["params"]
    print(f"[serve] restored legacy checkpoint (no embedded spec; "
          f"arch {cfg.name} taken from --arch)")
    return cfg, params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None,
                    help="architecture (default zamba2-7b; ignored — and "
                         "checked for conflicts — when --checkpoint embeds "
                         "a spec)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.checkpoint:
        cfg, params = load_serving_params(args.checkpoint, args.arch,
                                          seed=args.seed)
    else:
        cfg = get_arch(args.arch or "zamba2-7b").reduced()
        params = registry.init(jax.random.PRNGKey(args.seed), cfg)

    B = args.batch
    if cfg.arch_type == "audio":
        # the ServingLoop's synthetic traffic has no audio embeddings —
        # keep the direct decode path for encoder-decoder archs
        rng = jax.random.PRNGKey(args.seed)
        max_seq = args.prompt_len + args.tokens
        audio = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        cache = registry.init_cache(params, cfg, B, max_seq,
                                    audio_embeds=audio)
        step = jax.jit(registry.decode_fn(cfg, moe_path="dense"))
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (B, args.prompt_len), 0, cfg.vocab_size)
        for pos in range(args.prompt_len):
            logits, cache = step(params, cache, prompt[:, pos],
                                 jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1)
        t0 = time.perf_counter()
        generated = []
        for i in range(args.tokens):
            logits, cache = step(params, cache, tok,
                                 jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, axis=-1)
            generated.append(tok)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        ids = jnp.stack(generated, 1)
    else:
        from repro.core.engine.model_store import GlobalModelStore
        from repro.core.serve import ServingLoop
        store = GlobalModelStore(params=params)
        loop = ServingLoop(store, cfg, batch=B, prompt_len=args.prompt_len,
                           tokens=args.tokens, seed=args.seed)
        swap_us = loop.swap()
        ids, dt = loop.decode(loop._traffic(0))
        print(f"[serve] store snapshot v{loop.served_version} hot-swapped "
              f"in {swap_us:.0f}us")

    print(f"[serve] {cfg.name} ({cfg.arch_type}): batch={B}, "
          f"{args.tokens} tokens/seq, {B * args.tokens / dt:.1f} tok/s (CPU)")
    print(f"[serve] ids[0] = {ids[0].tolist()}")


if __name__ == "__main__":
    main()
