"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` provides HLO FLOPs and bytes accessed.
Collective bytes are NOT in cost_analysis: we parse the post-SPMD optimized
HLO (``compiled.as_text()``) and sum the result-shape bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment-given).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g.:  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups=...
# result may also be a tuple: (bf16[8]{0}, bf16[8]{0}) all-reduce(...)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective in optimized HLO.

    ``-start``/``-done`` async pairs are counted once (on ``-start``; the
    matching ``-done`` carries no payload of its own in our accounting).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
    return stats


@dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "collective_bytes": self.collective_bytes,
                "n_chips": self.n_chips, "compute_s": self.compute_s,
                "memory_s": self.memory_s, "collective_s": self.collective_s,
                "dominant": self.dominant}


def roofline(flops: float, bytes_accessed: float, collective_bytes: float,
             n_chips: int, links_per_chip: int = 4) -> RooflineTerms:
    """Three roofline terms in seconds (assignment formulas).

    cost_analysis() reports the whole (already SPMD-partitioned) module, i.e.
    per-chip work; we therefore divide the aggregate peak rates accordingly:
    compute_s = per_chip_flops / peak; memory_s = per_chip_bytes / hbm_bw;
    collective_s = per_chip_collective_bytes / (links * link_bw).
    """
    return RooflineTerms(
        flops=flops, bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes, n_chips=n_chips,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=collective_bytes / (links_per_chip * ICI_BW),
    )


def model_flops(param_count: int, tokens: int, active_param_count:
                Optional[int] = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)."""
    n = active_param_count if active_param_count is not None else param_count
    return 6.0 * n * tokens
