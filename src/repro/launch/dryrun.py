import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first init); smoke tests and benches do NOT go through this module
and keep seeing one CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all   (spawns a subprocess per case)

Each case writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory analysis, cost analysis, collective stats and roofline terms.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, get_shape
from repro.distributed import sharding
from repro.distributed.strategies import (fed_batch_specs, fed_weight_specs,
                                          make_fed_train_step,
                                          make_prefill_step, make_serve_step)
from repro.launch import hlo_analysis, hlo_loops
from repro.launch.mesh import make_production_mesh
from repro.models import registry

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# dry-run federated round geometry (see DESIGN.md §2.1)
K_LOCAL = 4

# archs that must use strategy B / 2d params (cross-silo regime): one client
# copy of the params per data lane (strategy A) only fits up to ~7B at bf16
# on 16-way model sharding (measured: gemma2-27b needs 3.4 GB/chip params
# alone -> ~17 GB with grads + round carry + f32 averaging).
SEQUENTIAL_ARCHS = {"gemma2-27b", "phi3.5-moe-42b-a6.6b", "llava-next-34b",
                    "mixtral-8x22b", "nemotron-4-340b"}


def should_skip(cfg, shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §2.5)")
    return None


def case_name(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}__{shape}__{mesh}"


def build_case(arch_name: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None):
    """Construct (step_fn, example_args, in_shardings, out_shardings, meta)."""
    overrides = overrides or {}
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dtype = jnp.bfloat16
    two_d = cfg.name in SEQUENTIAL_ARCHS or overrides.get("force_2d", False)
    two_d = overrides.get("two_d", two_d)
    strategy = "sequential" if cfg.name in SEQUENTIAL_ARCHS else "parallel"
    strategy = overrides.get("strategy", strategy)

    params_shapes = jax.eval_shape(
        lambda: registry.init(jax.random.PRNGKey(0), cfg, dtype))
    # multi-pod 2d archs also FSDP over the pod axis (512-way param sharding)
    fsdp_axes = ("data", "pod") if (two_d and multi_pod) else ("data",)
    pspecs = sharding.param_pspecs(cfg, params_shapes, mesh, two_d=two_d,
                                   fsdp_axes=fsdp_axes)
    p_shard = sharding.named(mesh, pspecs)
    meta: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy, "two_d_params": two_d,
        "param_count": registry.param_count(cfg),
        "active_param_count": registry.active_param_count(cfg),
    }

    if shape.kind == "train":
        n_clients = overrides.get("n_clients",
                                  32 if (multi_pod and strategy == "parallel") else 16)
        if strategy == "sequential":
            # multi-pod: the pod axis is spent on FSDP param sharding (the
            # 100B+ archs need the memory), so clients stay one sequential
            # scan; cross-pod client groups would need the pod axis twice.
            groups: Optional[int] = 1
        else:
            groups = None
        k_local = overrides.get("k_local", K_LOCAL)
        batches = fed_batch_specs(cfg, shape, n_clients=n_clients,
                                  k_local=k_local, groups=groups, dtype=dtype)
        weights = fed_weight_specs(n_clients, groups)
        b_specs = sharding.fed_batch_pspecs(batches, mesh, strategy)
        if strategy == "parallel":
            w_spec = P(sharding.client_axes(mesh))
        else:
            w_spec = P(None, None)
        # production default: Megatron-style sequence parallelism — the
        # residual stream is sharded over 'model' along the SEQUENCE dim, so
        # remat-saved boundaries shrink 16x while matmul layouts stay 1d
        # (ablation in EXPERIMENTS §Perf; sharding over d_model instead was
        # measured 6x WORSE — it fights the col/row-parallel weight layout)
        act_mode = overrides.get("act_spec", "seq")
        # strategy A: the client vmap dim carries 'data' (via spmd_axis_name);
        # strategy B: the per-client batch dim itself is data-sharded.
        b_ax = "data" if strategy == "sequential" else None
        act_spec = None
        if act_mode == "seq" and shape.seq_len % mesh.shape["model"] == 0:
            act_spec = P(b_ax, "model", None)
        elif act_mode == "model" and cfg.d_model % mesh.shape["model"] == 0:
            act_spec = P(b_ax, None, "model")
        if strategy == "parallel":
            spmd_axes = sharding.client_axes(mesh)
        else:
            spmd_axes = None
        tr_moe_path = overrides.get("moe_path", "dispatch")
        tr_moe_shards, tr_moe_axes = 1, None
        if (cfg.moe is not None and "moe_path" not in overrides
                and shape.seq_len % mesh.shape["model"] == 0):
            tr_moe_path = "dispatch_sharded"
            tr_moe_shards, tr_moe_axes = mesh.shape["model"], ("model",)
        step = make_fed_train_step(
            cfg, strategy=strategy,
            remat=overrides.get("remat", True),
            moe_path=tr_moe_path, moe_shards=tr_moe_shards,
            moe_spmd_axes=tr_moe_axes,
            use_kernel_avg=overrides.get("use_kernel_avg", False),
            act_spec=act_spec,
            acc_dtype=overrides.get("acc_dtype", jnp.bfloat16),
            client_spmd_axes=spmd_axes if act_spec is not None else None,
            param_specs=pspecs if strategy == "sequential" else None)
        eta = jax.ShapeDtypeStruct((), jnp.float32)
        args = (params_shapes, batches, weights, eta)
        in_sh = (p_shard, sharding.named(mesh, b_specs),
                 NamedSharding(mesh, w_spec), NamedSharding(mesh, P()))
        out_sh = (p_shard, NamedSharding(mesh, P()))
        meta.update(n_clients=n_clients, k_local=k_local, groups=groups or 0,
                    tokens_per_round=shape.global_batch * shape.seq_len * k_local)
        return step, args, in_sh, out_sh, meta

    long_mode = shape.name == "long_500k"
    ba = sharding.serve_batch_axes(mesh)
    ba_size = 1
    for a in ba:
        ba_size *= mesh.shape[a]
    B = shape.global_batch

    if shape.kind == "prefill":
        act_mode = overrides.get("act_spec", "seq")
        pf_act = None
        pf_b = ba if B % ba_size == 0 else None
        if act_mode == "seq" and shape.seq_len % mesh.shape["model"] == 0:
            pf_act = P(pf_b, "model", None)
        # when kv heads don't divide the model axis, shard the attention
        # key-sequence dim instead — keeps probs buffers sharded (measured:
        # 25.8 GB/chip unsharded probs on nemotron prefill without this)
        kv_spec = None
        if (cfg.num_kv_heads % mesh.shape["model"] != 0
                and shape.seq_len % mesh.shape["model"] == 0):
            kv_spec = P(pf_b, "model", None, None)
        # MoE: shard-local dispatch along the seq-sharded token axis —
        # the global argsort/scatter path compiles but leaves the capacity
        # buffers unsharded (mixtral prefill: 62.8 GB/chip measured)
        moe_path = overrides.get("moe_path", "dispatch")
        moe_shards, moe_axes = 1, None
        if cfg.moe is not None and shape.seq_len % mesh.shape["model"] == 0:
            moe_path = overrides.get("moe_path", "dispatch_sharded")
            moe_shards, moe_axes = mesh.shape["model"], ("model",)
        step = make_prefill_step(cfg, long_mode=long_mode, moe_path=moe_path,
                                 act_spec=pf_act, attn_kv_spec=kv_spec,
                                 moe_shards=moe_shards, moe_spmd_axes=moe_axes)
        inputs = registry.input_specs(cfg, shape, dtype=dtype)
        in_batch_specs = {}
        for k, v in inputs.items():
            bspec = P(*([ba if B % ba_size == 0 else None]
                        + [None] * (v.ndim - 1)))
            in_batch_specs[k] = NamedSharding(mesh, bspec)
        args = (params_shapes, inputs)
        in_sh = (p_shard, in_batch_specs)
        if registry.is_encdec(cfg):
            out_sh = None
        else:
            # explicit shardings for the returned decode states — GSPMD left
            # them replicated (100+ GB/chip on gemma2/nemotron, measured)
            with mesh:
                out_shapes = jax.eval_shape(step, params_shapes, inputs)
            state_specs = sharding.cache_pspecs(cfg, out_shapes[1], mesh)
            logit_spec = P(ba if B % ba_size == 0 else None,
                           "model" if cfg.vocab_size % mesh.shape["model"] == 0
                           else None)
            out_sh = (NamedSharding(mesh, logit_spec),
                      sharding.named(mesh, state_specs))
        meta.update(tokens=B * shape.seq_len)
        return step, args, in_sh, out_sh, meta

    # decode
    ring = overrides.get("ring", False)   # windowed ring caches (§Perf R1)
    kv_quant = overrides.get("kv_quant", False)  # int8 caches (§Perf Q-KV)
    cache_shapes = registry.cache_specs(cfg, B, shape.seq_len, dtype=dtype,
                                        ring=ring, long_mode=long_mode,
                                        quant=kv_quant)
    c_specs = sharding.cache_pspecs(cfg, cache_shapes, mesh)
    c_shard = sharding.named(mesh, c_specs)
    step = make_serve_step(cfg, long_mode=long_mode,
                           moe_path=overrides.get("moe_path", "dispatch"),
                           ring=ring)
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = NamedSharding(mesh, P(ba if B % ba_size == 0 else None))
    logit_spec = NamedSharding(
        mesh, P(ba if B % ba_size == 0 else None,
                "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None))
    args = (params_shapes, cache_shapes, token, pos)
    in_sh = (p_shard, c_shard, tok_spec, NamedSharding(mesh, P()))
    out_sh = (logit_spec, c_shard)
    meta.update(tokens=B)
    return step, args, in_sh, out_sh, meta


def run_case(arch_name: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict[str, Any]] = None,
             write: bool = True) -> Dict[str, Any]:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    name = case_name(arch_name, shape_name, multi_pod)
    skip = should_skip(cfg, shape)
    record: Dict[str, Any] = {"case": name, "arch": arch_name,
                              "shape": shape_name,
                              "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        if write:
            _write(record, name)
        return record

    t0 = time.time()
    step, args, in_sh, out_sh, meta = build_case(arch_name, shape_name,
                                                 multi_pod, overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # jax<=0.4.x returns [dict] per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # Loop-aware accounting: XLA:CPU cost_analysis counts while bodies once
    # (verified K=1 == K=4), so FLOPs/bytes/collectives are re-derived from
    # the optimized HLO with trip-count multipliers (hlo_loops).
    stats = hlo_loops.analyze(hlo)
    flops = stats.dot_flops                    # per chip
    bytes_accessed = stats.traffic_bytes       # per chip (fusion-boundary)
    terms = hlo_analysis.roofline(flops, bytes_accessed,
                                  stats.collective_bytes, n_chips)
    mf = hlo_analysis.model_flops(
        meta["param_count"], meta.get("tokens_per_round", meta.get("tokens", 0)),
        meta["active_param_count"])
    if shape.kind == "train":
        mf *= 3  # fwd + bwd

    record.update(meta)
    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_dict(mem),
        "cost_analysis_raw": {k: v for k, v in (cost or {}).items()
                              if isinstance(v, (int, float))},
        "collectives": {"counts": stats.collective_counts,
                        "bytes": stats.collective_bytes_by_op,
                        "total_bytes": stats.collective_bytes},
        "trip_counts": stats.trip_counts,
        "roofline": terms.as_dict(),
        "model_flops": mf,
        "useful_flops_ratio": (mf / (flops * n_chips)) if flops else None,
        "hlo_bytes": len(hlo),
    })
    print(f"[dryrun] {name}: status=ok compile={t_compile:.1f}s "
          f"flops/chip={flops:.3e} bytes/chip={bytes_accessed:.3e} "
          f"coll/chip={stats.collective_bytes:.3e}B dominant={terms.dominant}")
    print(f"[dryrun] memory_analysis: {record['memory_analysis']}")
    if write:
        _write(record, name)
    return record


def _mem_dict(mem) -> Dict[str, Any]:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def _write(record: Dict[str, Any], name: str) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, name + ".json"), "w") as f:
        json.dump(record, f, indent=2, default=str)


def all_cases():
    for arch in ARCHS:
        for shape in SHAPES:
            for multi_pod in (False, True):
                yield arch, shape, multi_pod


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) in subprocesses")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = []
        for arch, shape, mp in all_cases():
            name = case_name(arch, shape, mp)
            path = os.path.join(OUT_DIR, name + ".json")
            if args.skip_existing and os.path.exists(path):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp:
                cmd.append("--multi-pod")
            print(f"[dryrun --all] {name}", flush=True)
            r = subprocess.run(cmd, env={**os.environ})
            if r.returncode != 0:
                failures.append(name)
        print(f"[dryrun --all] done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        rec = run_case(args.arch, args.shape, args.multi_pod)
        return 0 if rec["status"] in ("ok", "skipped") else 1
    except Exception:
        traceback.print_exc()
        rec = {"case": case_name(args.arch, args.shape, args.multi_pod),
               "status": "error", "error": traceback.format_exc()}
        _write(rec, rec["case"])
        return 1


if __name__ == "__main__":
    sys.exit(main())
