"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices; smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
