"""Loop-aware accounting over optimized (post-SPMD) HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, ignoring
trip counts — useless for a train step that scans over K local steps and L/c
layer cycles (verified: K=1 and K=4 report identical FLOPs). This module
re-derives loop-aware totals directly from the HLO text:

1. split the module into computations;
2. find every ``while`` op, its body/condition computations, and the trip
   count (the ``s32[] constant(T)`` compared against the induction variable
   in the condition computation; LT -> T, LE -> T+1);
3. propagate multipliers from ENTRY through the while-nesting (and plain
   ``calls=``/``to_apply=`` edges with multiplier 1);
4. per computation, account:
   - dot FLOPs: 2 * prod(result dims) * prod(contracting dims),
   - collective result bytes (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute; ``-start``/``-done`` pairs once),
   - fusion-boundary traffic: result + operand bytes of top-level ops
     (parameters/constants/GTE/bitcast/tuple excluded) — an HBM-traffic
     estimate at the granularity roofline analysis needs.

All shapes in the optimized module are per-chip (post-partitioning), so
totals are per-chip; multiply by chip count for global numbers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# header line: `%name (params...) -> type {` or `ENTRY %name (...) -> ... {`
# (params may contain nested parens for tuple types, so match loosely)
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE = re.compile(r"while\(.*?\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_TRIP = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_NO_TRAFFIC = ("parameter(", "constant(", "get-tuple-element(", "bitcast(",
               "tuple(", "after-all(", "partition-id(", "replica-id(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    # name -> result type string (for operand lookup)
    shapes: Dict[str, str] = field(default_factory=dict)


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        cur.lines.append(line)
    return comps


def _result_type(rhs: str) -> str:
    """Everything before the op name, e.g. 'f32[16,4]{1,0} ' or tuple types."""
    # op name is the last bare word before '('
    m = re.search(r"([\w\-]+)\(", rhs)
    return rhs[: m.start()] if m else rhs


@dataclass
class LoopAwareStats:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_op: Dict[str, float] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)
    unparsed_trips: int = 0


def _condition_trip(comp: Computation) -> Optional[int]:
    text = "\n".join(comp.lines)
    consts = _TRIP.findall(text)
    if not consts:
        return None
    trip = int(consts[-1])
    if "direction=LE" in text:
        trip += 1
    return trip


def analyze(hlo: str) -> LoopAwareStats:
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    stats = LoopAwareStats()
    if entry is None:
        return stats

    # per-computation edges: (child_name, multiplier)
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for comp in comps.values():
        if comp.name == "__entry__":
            continue
        e: List[Tuple[str, int]] = []
        for line in comp.lines:
            wm = _WHILE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trip = None
                if cond_name in comps:
                    trip = _condition_trip(comps[cond_name])
                if trip is None:
                    trip = 1
                    stats.unparsed_trips += 1
                stats.trip_counts[body_name] = trip
                e.append((body_name, trip))
                continue
            for cal in _CALLS.findall(line):
                if cal in comps:
                    e.append((cal, 1))
        edges[comp.name] = e

    # propagate multipliers from entry (graph is a DAG of computations)
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        mult[name] = mult.get(name, 0) + m
        for child, k in edges.get(name, []):
            visit(child, m * k)

    visit(entry.name, 1)

    # account per computation
    for comp in comps.values():
        if comp.name == "__entry__":
            continue
        m = mult.get(comp.name, 0)
        if m == 0:
            continue
        pending_ops: Dict[str, str] = {}  # name -> result type (for operands)
        for line in comp.lines:
            om = _OP_LINE.match(line)
            if not om:
                continue
            rhs = om.group(2)
            pending_ops[om.group(1)] = _result_type(rhs)
            if any(sk in rhs for sk in _NO_TRAFFIC):
                continue
            rtype = _result_type(rhs)
            rbytes = _shape_bytes(rtype)

            # collectives (count -start once, skip -done)
            cm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|"
                           r"all-to-all|collective-permute)(-start|-done)?\(",
                           rhs)
            if cm:
                if cm.group(2) == "-done":
                    continue
                op = cm.group(1)
                stats.collective_counts[op] = stats.collective_counts.get(op, 0) + m
                stats.collective_bytes_by_op[op] = \
                    stats.collective_bytes_by_op.get(op, 0.0) + rbytes * m
                stats.collective_bytes += rbytes * m
                stats.traffic_bytes += rbytes * m
                continue

            if re.search(r"\bdot\(", rhs):
                flops = _dot_flops(rhs, pending_ops)
                stats.dot_flops += flops * m

            if " while(" in rhs or rhs.startswith("while("):
                continue  # body accounted separately
            # traffic: result + named operands
            t = rbytes
            args = re.search(r"\(([^)]*)\)", rhs[rhs.find("("):])
            if args:
                for a in re.findall(r"%([\w.\-]+)", args.group(1)):
                    if a in pending_ops:
                        t += _shape_bytes(pending_ops[a])
            stats.traffic_bytes += t * m
    return stats


def _dot_flops(rhs: str, shapes: Dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dim sizes)."""
    rd = _shape_dims(_result_type(rhs))
    if rd is None:
        return 0.0
    _, rdims = rd
    out = 1
    for d in rdims:
        out *= d
    lhs_m = re.search(r"dot\(%([\w.\-]+),", rhs)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if not lhs_m or not cm or lhs_m.group(1) not in shapes:
        return 2.0 * out  # contracted size unknown; lower bound
    ld = _shape_dims(shapes[lhs_m.group(1)])
    if ld is None:
        return 2.0 * out
    _, ldims = ld
    contract = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(ldims):
            contract *= ldims[int(idx)]
    return 2.0 * out * contract
