"""Fleet driver — one spec, an override grid, one device budget (§12).

Fans a base ``ExperimentSpec`` over a dotted-path sweep grid and runs every
point through two fleet-wide mechanisms:

  * **cross-experiment executable sharing** — all points compile into one
    process-level ``ExecutableRegistry``; points whose program fingerprint
    (``sweep.spec_program_key`` + mesh slice devices) and bucket input
    signatures coincide compile once and dispatch N times. With
    ``--share-k-grid`` the driver pins one ``fed.k_grid0`` anchor (the max
    ``fed.k0`` in the grid) so a ``fed.k0`` sweep collapses onto one bucket
    signature — 100% executable reuse across points.
  * **one-mesh experiment packing** — points run concurrently, each on its
    own backend slice (``ExecutionBackend.fleet_slices``: sub-meshes carved
    from a MeshBackend's device grid; fresh LocalBackends interleaving on
    the single-device dispatch queue), with per-point prefetch threads
    overlapping host batch builds. Small-model sweeps saturate the device
    instead of serialising warm-up after warm-up.

The result is one consolidated leaderboard/CSV: final/min loss, rounds/sec,
encoded up/down wire, peak executable MB and exact compile/shared/dispatch
counters per point.

    PYTHONPATH=src python -m repro.launch.fleet \\
        --sweep fed.k0=2,4,8 transport.name=int8,topk -- --rounds 20
    PYTHONPATH=src python -m repro.launch.train --spec run.json \\
        --sweep fed.k0=2,4,8 --share-k-grid

Opt-in warm-start across *invocations*: ``--compile-cache DIR`` wires
JAX's persistent compilation cache, so a repeated fleet skips XLA compiles
entirely.
"""
from __future__ import annotations

import argparse
import csv
import os
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

from repro.api import ExperimentSpec, build
from repro.api.sweep import SweepPoint, expand_sweep, spec_program_key
from repro.core.engine.round import ExecutableRegistry
from repro.core.mem import trainer_peak_mb

CSV_FIELDS = ("label", "overrides", "final_loss", "min_loss", "rounds",
              "wall_s", "rounds_per_sec", "uplink_mbit", "downlink_mbit",
              "peak_mb", "compiles", "shared", "dispatches")


def enable_persistent_cache(path: str) -> bool:
    """Opt-in JAX persistent compilation cache: repeated fleet invocations
    reload AOT executables from ``path`` instead of re-compiling. Returns
    False (without raising) on runtimes that don't support it — the fleet
    still runs, just cold."""
    try:
        import jax
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        return True
    except Exception:
        return False


@dataclass(frozen=True)
class PointResult:
    """One sweep point's consolidated row."""
    label: str
    overrides: Tuple[str, ...]
    spec: ExperimentSpec
    final_loss: float
    min_loss: float
    rounds: int
    wall_s: float
    rounds_per_sec: float
    uplink_mbit: float
    downlink_mbit: float
    peak_mb: float
    compile_count: int
    shared_count: int
    dispatch_count: int

    def as_row(self) -> dict:
        return {"label": self.label, "overrides": " ".join(self.overrides),
                "final_loss": f"{self.final_loss:.6f}",
                "min_loss": f"{self.min_loss:.6f}",
                "rounds": self.rounds, "wall_s": f"{self.wall_s:.3f}",
                "rounds_per_sec": f"{self.rounds_per_sec:.3f}",
                "uplink_mbit": f"{self.uplink_mbit:.2f}",
                "downlink_mbit": f"{self.downlink_mbit:.2f}",
                "peak_mb": f"{self.peak_mb:.2f}",
                "compiles": self.compile_count,
                "shared": self.shared_count,
                "dispatches": self.dispatch_count}


@dataclass
class FleetResult:
    points: List[PointResult]
    wall_s: float              # whole-fleet wall clock
    packed: bool
    compile_count: int         # distinct executables compiled fleet-wide
    shared_count: int          # per-point registry adoptions, summed
    dispatch_count: int

    def leaderboard(self) -> str:
        """Text table, best final loss first."""
        rows = sorted(self.points, key=lambda p: p.final_loss)
        head = (f"{'label':<28} {'loss':>9} {'min':>9} {'r/s':>7} "
                f"{'up':>8} {'down':>8} {'peakMB':>7} {'cmp':>4} {'shr':>4}")
        lines = [head, "-" * len(head)]
        for p in rows:
            lines.append(
                f"{p.label:<28} {p.final_loss:>9.4f} {p.min_loss:>9.4f} "
                f"{p.rounds_per_sec:>7.2f} {p.uplink_mbit:>8.1f} "
                f"{p.downlink_mbit:>8.1f} {p.peak_mb:>7.1f} "
                f"{p.compile_count:>4d} {p.shared_count:>4d}")
        lines.append(f"fleet: {len(self.points)} point(s) in "
                     f"{self.wall_s:.2f}s ({'packed' if self.packed else 'serial'}), "
                     f"{self.compile_count} compile(s), "
                     f"{self.shared_count} shared, "
                     f"{self.dispatch_count} dispatch(es)")
        return "\n".join(lines)

    def to_csv(self, path: str) -> None:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=CSV_FIELDS)
            w.writeheader()
            for p in sorted(self.points, key=lambda p: p.final_loss):
                w.writerow(p.as_row())


def _program_key_for(spec: ExperimentSpec, backend) -> Tuple:
    """Registry program key for one packed point: the spec fingerprint plus
    the slice's device ids — AOT executables are bound to devices, so two
    points on different sub-meshes must never share an entry."""
    key = spec_program_key(spec)
    mesh = getattr(backend, "mesh", None)
    if mesh is not None:
        key = key + (("devices", tuple(int(d.id) for d in
                                       mesh.devices.flat)),)
    return key


def share_k_grid(points: Sequence[SweepPoint]) -> List[SweepPoint]:
    """Pin one ``quantize_k`` anchor — the grid's max ``fed.k0`` — on every
    point (forcing ``fed.k_quantize`` on), so points differing only in
    ``fed.k0`` snap to identical K values and share bucket executables."""
    anchor = max(p.spec.fed.k0 for p in points)
    out = []
    for p in points:
        spec = p.spec.with_overrides("fed.k_quantize=true",
                                     f"fed.k_grid0={anchor}").validate()
        out.append(SweepPoint(label=p.label, overrides=p.overrides,
                              spec=spec))
    return out


def _run_point(point: SweepPoint, backend, registry: ExecutableRegistry,
               rounds: Optional[int], verbose: bool) -> PointResult:
    program_key = _program_key_for(point.spec, backend) \
        if registry is not None else None
    exp = build(point.spec, backend=backend, registry=registry,
                program_key=program_key)
    t0 = time.perf_counter()
    h = exp.run(rounds, verbose=False)
    wall = time.perf_counter() - t0
    tr = exp.trainer
    n = len(h.rounds)
    res = PointResult(
        label=point.label, overrides=point.overrides, spec=point.spec,
        final_loss=float(h.train_loss[-1]) if h.train_loss else float("nan"),
        min_loss=float(min(h.min_train_loss)) if h.min_train_loss
        else float("nan"),
        rounds=n, wall_s=wall,
        rounds_per_sec=n / wall if wall > 0 else 0.0,
        uplink_mbit=float(h.uplink_mbit[-1]) if h.uplink_mbit else 0.0,
        downlink_mbit=float(h.downlink_mbit[-1]) if h.downlink_mbit else 0.0,
        peak_mb=trainer_peak_mb(tr),
        compile_count=tr.compile_count, shared_count=tr.shared_count,
        dispatch_count=tr.dispatch_count)
    if verbose:
        print(f"[fleet] {res.label}: loss {res.final_loss:.4f} in "
              f"{res.wall_s:.2f}s ({res.compile_count} compiled, "
              f"{res.shared_count} shared)")
    return res


def _slices_for(points: Sequence[SweepPoint], packed: bool) -> List[Any]:
    """One backend per point. Packed fleets with a single backend section
    carve slices from ONE parent backend (sub-meshes / fresh local
    instances); mixed-backend grids and serial fleets let ``build`` derive
    each point's backend from its own spec (None)."""
    if not packed:
        return [None] * len(points)
    from repro.api.experiment import _make_backend
    sections = {p.spec.backend for p in points}
    if len(sections) != 1:
        return [None] * len(points)
    parent = _make_backend(points[0].spec)
    return parent.fleet_slices(len(points))


def run_fleet(base: Optional[ExperimentSpec] = None,
              sweep: Sequence[str] = (), *,
              points: Optional[Sequence[SweepPoint]] = None,
              packed: bool = True, workers: Optional[int] = None,
              rounds: Optional[int] = None,
              registry: Optional[ExecutableRegistry] = None,
              share_grid: bool = False,
              verbose: bool = False) -> FleetResult:
    """Run a sweep as one fleet.

    ``base`` + ``sweep`` expand through ``expand_sweep`` (or pass
    pre-expanded ``points``). ``packed=True`` runs points concurrently on
    backend slices; False runs them serially (still sharing the registry).
    ``share_grid`` pins a fleet-wide ``fed.k_grid0`` anchor. ``registry``
    defaults to a fresh fleet-wide ``ExecutableRegistry``."""
    if points is None:
        points = expand_sweep(*sweep, base=base)
    points = list(points)
    if not points:
        raise ValueError("run_fleet: empty sweep grid")
    if share_grid:
        points = share_k_grid(points)
    registry = registry if registry is not None else ExecutableRegistry()
    backends = _slices_for(points, packed)
    t0 = time.perf_counter()
    if packed and len(points) > 1:
        n_workers = workers if workers else len(points)
        with ThreadPoolExecutor(max_workers=n_workers) as pool:
            futures = [pool.submit(_run_point, p, b, registry, rounds,
                                   verbose)
                       for p, b in zip(points, backends)]
            results = [f.result() for f in futures]
    else:
        results = [_run_point(p, b, registry, rounds, verbose)
                   for p, b in zip(points, backends)]
    wall = time.perf_counter() - t0
    return FleetResult(
        points=results, wall_s=wall, packed=packed,
        compile_count=registry.compile_count,
        shared_count=sum(r.shared_count for r in results),
        dispatch_count=sum(r.dispatch_count for r in results))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--spec", default=None, metavar="FILE.json",
                    help="base ExperimentSpec (default: ExperimentSpec())")
    ap.add_argument("--set", action="append", default=[], metavar="PATH=V",
                    dest="overrides",
                    help="base-spec dotted-path override, repeatable")
    ap.add_argument("--sweep", nargs="+", default=[], metavar="PATH=V1,V2",
                    help="sweep axes, e.g. --sweep fed.k0=2,4,8 "
                         "transport.name=int8,topk (cross product)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds per point (default: each spec's "
                         "fed.rounds)")
    ap.add_argument("--serial", action="store_true",
                    help="run points one after another instead of packed "
                         "(still shares the executable registry)")
    ap.add_argument("--workers", type=int, default=None,
                    help="max concurrent packed points (default: all)")
    ap.add_argument("--share-k-grid", action="store_true",
                    help="pin fed.k_grid0 to the grid's max fed.k0 so k0 "
                         "sweep points share bucket executables")
    ap.add_argument("--csv", default=None, metavar="FILE.csv",
                    help="write the consolidated leaderboard CSV here")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable JAX's persistent compilation cache in DIR "
                         "(warm-start repeated fleet invocations)")
    ap.add_argument("--quiet", action="store_true")
    return ap


def main(argv=None) -> FleetResult:
    args = make_parser().parse_args(argv)
    if args.compile_cache:
        ok = enable_persistent_cache(args.compile_cache)
        print(f"[fleet] persistent compile cache: "
              f"{'on, ' + args.compile_cache if ok else 'unavailable'}")
    base = ExperimentSpec.load(args.spec) if args.spec else ExperimentSpec()
    if args.overrides:
        base = base.with_overrides(*args.overrides)
    if not args.sweep:
        raise SystemExit("fleet: --sweep is required (e.g. --sweep "
                         "fed.k0=2,4,8)")
    result = run_fleet(base, args.sweep, packed=not args.serial,
                       workers=args.workers, rounds=args.rounds,
                       share_grid=args.share_k_grid,
                       verbose=not args.quiet)
    print(result.leaderboard())
    if args.csv:
        result.to_csv(args.csv)
        print(f"[fleet] csv -> {args.csv}")
    return result


if __name__ == "__main__":
    main()
