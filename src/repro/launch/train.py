"""Production federated-training launcher.

Composes: an assigned architecture config (optionally reduced for CPU), the
synthetic federated data pipeline, the FedAvg engine with the paper's decay
schedules, the Eq. 3-5 runtime model, and checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \\
        --rounds 50 --k-schedule rounds --checkpoint /tmp/ckpt

The trainer is driven through an execution backend (DESIGN.md §7):
``--backend local`` is the single-device engine; ``--backend mesh`` runs the
SAME FedAvgTrainer (K-bucketed scans, server optimizers, robust
aggregators) through a ``MeshBackend`` — the client axis is placed on the
mesh ``data`` axis, batches are ``device_put`` with the client sharding from
the prefetch thread, and ``--aggregator kernel`` routes aggregation through
the client-sharded Pallas reduction. On CPU the mesh is the degenerate
(devices x 1) data x model mesh, so the identical code path that runs on a
pod is exercised end-to-end here.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_arch
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel
from repro.core.engine import MeshBackend
from repro.data import make_lm_clients
from repro.models import registry


def make_backend(name: str, strategy: str, groups: int):
    """``local`` -> None (the engine's LocalBackend default); ``mesh`` ->
    a MeshBackend on a (devices, 1) data x model mesh."""
    if name == "local":
        return None
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    return MeshBackend(mesh, strategy=strategy, groups=groups)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--clients-per-round", type=int, default=6)
    ap.add_argument("--k0", type=int, default=8)
    ap.add_argument("--eta0", type=float, default=0.05)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-schedule", default="rounds",
                    choices=("fixed", "rounds", "error", "step", "cosine", "dsgd"))
    ap.add_argument("--eta-schedule", default="fixed",
                    choices=("fixed", "rounds", "error", "step"))
    ap.add_argument("--k-quantize", action="store_true")
    ap.add_argument("--server-optimizer", default="avg",
                    choices=("avg", "fedadam", "fedavgm", "fedyogi"))
    ap.add_argument("--aggregator", default="mean",
                    choices=("mean", "kernel", "median", "trimmed_mean"))
    ap.add_argument("--transport", default="none",
                    choices=("none", "int8", "int8x2", "topk"),
                    help="client-delta wire codec (DESIGN.md §8): int8 = "
                         "Q-KV int8 + server-side error feedback (~4x "
                         "uplink); int8x2 = two-level int8 on the wire "
                         "(~2x, no feedback state); topk = magnitude "
                         "top-k + error feedback")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="kept coordinate fraction for --transport topk")
    ap.add_argument("--backend", default="local", choices=("local", "mesh"),
                    help="execution backend: single-device or GSPMD mesh")
    ap.add_argument("--strategy", default="parallel",
                    choices=("parallel", "sequential"),
                    help="mesh client fan-out (ignored for --backend local)")
    ap.add_argument("--groups", type=int, default=1,
                    help="sequential-strategy client groups (hierarchical FL)")
    ap.add_argument("--bucket-rounds", type=int, default=8,
                    help="max rounds per jitted K-bucket scan")
    ap.add_argument("--feedback-bucket", type=int, default=1,
                    help="bucket length for error/step schedules")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the background batch prefetch thread")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_params = registry.param_count(cfg)
    print(f"[train] {cfg.name}: {n_params:,} params, "
          f"K-schedule={args.k_schedule}, eta-schedule={args.eta_schedule}")

    data = make_lm_clients(np.random.default_rng(args.seed),
                           num_clients=args.clients, vocab=cfg.vocab_size,
                           seq_len=args.seq)
    model_loss = registry.loss_fn(cfg, moe_path="dense")
    loss_fn = lambda p, b: model_loss(p, {"tokens": b["x"]})

    fed = FedConfig(total_clients=args.clients,
                    clients_per_round=args.clients_per_round,
                    rounds=args.rounds, k0=args.k0, eta0=args.eta0,
                    batch_size=args.batch_size,
                    loss_window=max(args.rounds // 8, 3),
                    k_schedule=args.k_schedule, eta_schedule=args.eta_schedule,
                    k_quantize=args.k_quantize,
                    server_optimizer=args.server_optimizer,
                    aggregator=args.aggregator,
                    transport=args.transport, topk_frac=args.topk_frac,
                    bucket_rounds=args.bucket_rounds,
                    feedback_bucket_rounds=args.feedback_bucket,
                    prefetch=not args.no_prefetch, seed=args.seed)
    rt = RuntimeModel(n_params * 32 / 1e6, RuntimeModelConfig(beta_seconds=0.05),
                      fed.clients_per_round)
    params = registry.init(jax.random.PRNGKey(args.seed), cfg)
    backend = make_backend(args.backend, args.strategy, args.groups)
    trainer = FedAvgTrainer(loss_fn, params, data, fed, rt, backend=backend)
    if trainer.engine.transport is not None:
        print(f"[train] transport={args.transport}: uplink "
              f"{rt.uplink_compression:.2f}x compressed "
              f"({rt.uplink_mbit_per_client:.2f} of {rt.size:.2f} mbit "
              f"per client-round)")
    h = trainer.run(args.rounds, verbose=False)
    print(f"[train] engine[{args.backend}]: {trainer.compile_count} bucket "
          f"executable(s) compiled, {trainer.engine.dispatch_count} "
          f"dispatch(es) for {args.rounds} rounds")
    step = max(args.rounds // 10, 1)
    for i in range(0, args.rounds, step):
        print(f"[train] round {h.rounds[i]:4d} K={h.k[i]:3d} "
              f"eta={h.eta[i]:.4f} loss={h.train_loss[i]:.4f} "
              f"simW={h.wall_clock_s[i]:.0f}s steps={h.sgd_steps[i]}")
    print(f"[train] final loss {h.train_loss[-1]:.4f} "
          f"(start {h.train_loss[0]:.4f}); total steps {h.sgd_steps[-1]}, "
          f"simulated wall-clock {h.wall_clock_s[-1]:.0f}s, "
          f"uplink {h.uplink_mbit[-1]:.0f} mbit")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, trainer.params,
                        meta={"arch": cfg.name, "rounds": args.rounds,
                              "k_schedule": args.k_schedule,
                              "final_loss": h.train_loss[-1]})
        print(f"[train] checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
