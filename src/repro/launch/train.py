"""Production federated-training launcher, driven by an ExperimentSpec.

Two front doors, one composition root (``repro.api.build``):

  * declarative — ``--spec examples/specs/local-int8-decayK.json`` plus any
    number of ``--set section.field=value`` dotted-path overrides;
  * legacy flags — the historical ``--arch/--rounds/--k-schedule/...``
    surface, now a thin translation layer that builds the SAME spec
    (bitwise-identical runs to the pre-spec launcher).

The resolved spec is printed before the run (and is itself valid ``--spec``
input), so every invocation leaves a reproducible artifact. With
``--checkpoint`` the final state is saved with the spec embedded —
``FederatedExperiment.restore(path)`` rebuilds the exact trainer.

    PYTHONPATH=src python -m repro.launch.train --spec run.json \\
        --set fed.rounds=100 --set transport.name=topk
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \\
        --rounds 50 --k-schedule rounds --checkpoint /tmp/ckpt
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import ExperimentSpec, build
from repro.configs import ARCHS


def make_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    # --- declarative front door -------------------------------------
    ap.add_argument("--spec", default=None, metavar="FILE.json",
                    help="load a full ExperimentSpec; legacy flags below "
                         "are ignored except --rounds/--checkpoint")
    ap.add_argument("--set", action="append", default=[], metavar="PATH=V",
                    dest="overrides",
                    help="dotted-path spec override, repeatable "
                         "(e.g. --set fed.k0=4 --set transport.name=int8)")
    ap.add_argument("--sweep", nargs="+", default=[], metavar="PATH=V1,V2",
                    help="fan the resolved spec over a sweep grid and run "
                         "it as a packed fleet (repro.launch.fleet), e.g. "
                         "--sweep fed.k0=2,4,8 transport.name=int8,topk")
    ap.add_argument("--sweep-csv", default=None, metavar="FILE.csv",
                    help="write the fleet leaderboard CSV here (--sweep)")
    ap.add_argument("--share-k-grid", action="store_true",
                    help="with --sweep: pin one fed.k_grid0 anchor so k0 "
                         "points share bucket executables")
    ap.add_argument("--serial-sweep", action="store_true",
                    help="with --sweep: run points serially instead of "
                         "packed")
    # --- legacy flags (translated to a spec) ------------------------
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=None,
                    help="round count (also applies on top of --spec)")
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--clients-per-round", type=int, default=6)
    ap.add_argument("--k0", type=int, default=8)
    ap.add_argument("--eta0", type=float, default=0.05)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-schedule", default="rounds",
                    choices=("fixed", "rounds", "error", "step", "cosine", "dsgd"))
    ap.add_argument("--eta-schedule", default="fixed",
                    choices=("fixed", "rounds", "error", "step"))
    ap.add_argument("--k-quantize", action="store_true")
    ap.add_argument("--server-optimizer", default="avg",
                    choices=("avg", "fedadam", "fedavgm", "fedyogi"))
    ap.add_argument("--aggregator", default="mean",
                    choices=("mean", "kernel", "median", "trimmed_mean"))
    ap.add_argument("--transport", default="none",
                    choices=("none", "int8", "int8x2", "topk"),
                    help="client-delta wire codec (DESIGN.md §8)")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="kept coordinate fraction for --transport topk")
    ap.add_argument("--downlink", default="none",
                    choices=("none", "int8", "int8x2", "topk", "adaptive"),
                    help="server broadcast codec: delta vs the last "
                         "broadcast reference (DESIGN.md §8.6; 'adaptive' "
                         "picks skip/int8/int8x2 per round, §10)")
    ap.add_argument("--ref-store", default="f32", choices=("f32", "q8"),
                    help="server-held downlink reference/residual store "
                         "(q8: two-level int8, ~2x less state, §10.3)")
    ap.add_argument("--aggregation", default="sync",
                    choices=("sync", "async"),
                    help="server aggregation policy: round-synchronous "
                         "FedAvg or FedBuff-style async buffering on the "
                         "simulated event clock (DESIGN.md §13)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: apply the buffer after this many client "
                         "arrivals (default: the cohort size)")
    ap.add_argument("--staleness-weight", default="constant",
                    choices=("constant", "inv", "poly"),
                    help="async: per-arrival contribution scale vs "
                         "staleness s — 1, 1/(1+s), or (1+s)^-0.5")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="async: drop arrivals staler than this many "
                         "versions (default: keep all)")
    ap.add_argument("--sampler", default="uniform",
                    choices=("uniform", "weighted", "fixed_cohort",
                             "availability"),
                    help="client participation policy (DESIGN.md §9.3)")
    ap.add_argument("--availability", type=float, default=0.9,
                    help="per-round online probability for "
                         "--sampler availability")
    ap.add_argument("--backend", default="local", choices=("local", "mesh"),
                    help="execution backend: single-device or GSPMD mesh")
    ap.add_argument("--strategy", default="parallel",
                    choices=("parallel", "sequential"),
                    help="mesh client fan-out (ignored for --backend local)")
    ap.add_argument("--groups", type=int, default=1,
                    help="sequential-strategy client groups (hierarchical FL)")
    ap.add_argument("--bucket-rounds", type=int, default=8,
                    help="max rounds per jitted K-bucket scan")
    ap.add_argument("--feedback-bucket", type=int, default=1,
                    help="bucket length for error/step schedules")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the background batch prefetch thread")
    ap.add_argument("--serve-every", type=int, default=None,
                    help="serve-while-training: hot-swap the global model "
                         "into a live decode service and tick it every N "
                         "rounds / buffer applies (DESIGN.md §14; also "
                         "applies on top of --spec)")
    ap.add_argument("--serve-qps", type=float, default=None,
                    help="modelled decode queries/sec the server answers "
                         "alongside training (stretches the round clock by "
                         "1/(1-rho); also applies on top of --spec)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def spec_from_legacy_args(args) -> ExperimentSpec:
    """Translate the historical flag surface into an ExperimentSpec.

    The resulting build reproduces the pre-spec launcher bit-for-bit: same
    data rng seeding, same param init, same FedConfig derivation (including
    the ``loss_window = max(rounds // 8, 3)`` rule and the beta=0.05s
    runtime constant)."""
    rounds = args.rounds if args.rounds is not None else 50
    # Optional async knobs only override when set: the spec refuses
    # buffer_size/max_staleness under aggregation="sync", and None is not
    # expressible as a dotted-path literal.
    async_overrides = [f"fed.aggregation={args.aggregation}",
                       f"fed.staleness_weight={args.staleness_weight}"]
    if args.buffer_size is not None:
        async_overrides.append(f"fed.buffer_size={args.buffer_size}")
    if args.max_staleness is not None:
        async_overrides.append(f"fed.max_staleness={args.max_staleness}")
    return ExperimentSpec().with_overrides(
        f"model.arch={args.arch}", f"model.reduced={args.reduced}",
        f"data.clients={args.clients}", f"data.seq_len={args.seq}",
        f"data.seed={args.seed}",
        f"fed.rounds={rounds}",
        f"fed.clients_per_round={args.clients_per_round}",
        f"fed.k0={args.k0}", f"fed.eta0={args.eta0}",
        f"fed.batch_size={args.batch_size}",
        f"fed.loss_window={max(rounds // 8, 3)}",
        f"fed.k_schedule={args.k_schedule}",
        f"fed.eta_schedule={args.eta_schedule}",
        f"fed.k_quantize={args.k_quantize}",
        f"fed.server_optimizer={args.server_optimizer}",
        f"fed.aggregator={args.aggregator}",
        f"fed.bucket_rounds={args.bucket_rounds}",
        f"fed.feedback_bucket_rounds={args.feedback_bucket}",
        f"fed.prefetch={not args.no_prefetch}",
        f"fed.seed={args.seed}",
        f"sampler.name={args.sampler}",
        f"sampler.availability={args.availability}",
        f"transport.name={args.transport}",
        f"transport.topk_frac={args.topk_frac}",
        f"transport.downlink={args.downlink}",
        f"transport.ref_store={args.ref_store}",
        f"backend.name={args.backend}", f"backend.strategy={args.strategy}",
        f"backend.groups={args.groups}",
        "runtime.beta_seconds=0.05",
        *async_overrides)


def resolve_spec(args) -> ExperimentSpec:
    if args.spec:
        spec = ExperimentSpec.load(args.spec)
        if args.rounds is not None:
            spec = spec.with_overrides(f"fed.rounds={args.rounds}")
    else:
        spec = spec_from_legacy_args(args)
    if args.overrides:
        spec = spec.with_overrides(*args.overrides)
    if args.serve_every is not None:
        spec = spec.with_overrides(f"serve.every={args.serve_every}")
    if args.serve_qps is not None:
        spec = spec.with_overrides(f"serve.qps={args.serve_qps}")
    return spec


def main(argv=None):
    args = make_parser().parse_args(argv)
    spec = resolve_spec(args).validate()
    if args.sweep:
        from repro.launch.fleet import run_fleet
        result = run_fleet(spec, args.sweep, packed=not args.serial_sweep,
                           rounds=args.rounds,
                           share_grid=args.share_k_grid,
                           verbose=True)
        print(result.leaderboard())
        if args.sweep_csv:
            result.to_csv(args.sweep_csv)
            print(f"[train] fleet csv -> {args.sweep_csv}")
        return result
    print("[train] resolved spec:")
    print(spec.to_json())

    exp = build(spec)
    trainer = exp.trainer
    rounds = spec.fed.rounds
    print(f"[train] {exp.label}: K-schedule={spec.fed.k_schedule}, "
          f"eta-schedule={spec.fed.eta_schedule}, "
          f"sampler={spec.sampler.name}, backend={spec.backend.name}")
    if spec.fed.aggregation == "async":
        print(f"[train] aggregation=async: buffer_size="
              f"{trainer.buffer_size}, "
              f"staleness_weight={spec.fed.staleness_weight}, "
              f"max_staleness={spec.fed.max_staleness}")
    engine = getattr(trainer, "engine", None)   # sync-only wire summaries
    transport = engine.transport if engine is not None else trainer.transport
    if transport is not None:
        rt = trainer.runtime
        ef = transport.ef_slots
        print(f"[train] transport={spec.transport.name}: uplink "
              f"{rt.uplink_compression:.2f}x compressed "
              f"({rt.uplink_mbit_per_client:.2f} of {rt.size:.2f} mbit "
              f"per client-round)"
              + (f", per-client EF x{ef}" if ef else ""))
    if engine is not None and engine.downlink is not None:
        rt = trainer.runtime
        print(f"[train] downlink={spec.transport.downlink}: broadcast "
              f"{rt.downlink_compression:.2f}x compressed "
              f"({rt.downlink_mbit_per_client:.2f} of {rt.size:.2f} mbit "
              f"per client-round)")

    h = exp.run()
    print(f"[train] engine[{spec.backend.name}]: {trainer.compile_count} "
          f"bucket executable(s) compiled, {trainer.dispatch_count} "
          f"dispatch(es) for {rounds} rounds")
    if spec.fed.aggregation == "async":
        print(f"[train] async: {trainer.applied_updates} updates applied, "
              f"{trainer.dropped_updates} dropped, mean staleness "
              f"{float(np.mean(h.staleness)) if h.staleness else 0.0:.2f}, "
              f"event-clock wall {h.wall_clock_s[-1]:.0f}s")
    step = max(rounds // 10, 1)
    for i in range(0, rounds, step):
        print(f"[train] round {h.rounds[i]:4d} K={h.k[i]:3d} "
              f"eta={h.eta[i]:.4f} loss={h.train_loss[i]:.4f} "
              f"simW={h.wall_clock_s[i]:.0f}s steps={h.sgd_steps[i]}")
    if spec.serve.every and h.serve_rounds:
        print(f"[train] serve: {len(h.serve_rounds)} tick(s), "
              f"{float(np.mean(h.serve_tokens_per_sec)):.0f} tok/s mean, "
              f"swap {float(np.mean(h.serve_swap_us)):.0f}us mean, "
              f"staleness <= {max(h.serve_staleness)}, "
              f"served version {trainer.serving.served_version} of "
              f"{trainer.store.version}")
    print(f"[train] final loss {h.train_loss[-1]:.4f} "
          f"(start {h.train_loss[0]:.4f}); total steps {h.sgd_steps[-1]}, "
          f"simulated wall-clock {h.wall_clock_s[-1]:.0f}s, "
          f"uplink {h.uplink_mbit[-1]:.0f} mbit, "
          f"downlink {h.downlink_mbit[-1]:.0f} mbit")
    if args.checkpoint:
        exp.save(args.checkpoint)
        print(f"[train] checkpoint (spec embedded) -> {args.checkpoint}")


if __name__ == "__main__":
    main()
