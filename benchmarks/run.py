"""Benchmark harness — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
                                            [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows:
  * fig12_*    — Fig. 1/2 analogue: schedule comparison on synthetic
                 non-IID paper tasks under the Eq. 5 runtime model
  * table4_*   — Table 4: relative SGD steps + wall-clock speedup
  * roofline_* — per (arch x shape x mesh) roofline terms from the dry-run
  * kern_*     — Pallas kernel micro-benchmarks (interpret mode)

``--json PATH`` additionally writes the machine-readable gate records —
the kernel suite's (kernel/oracle µs + max-abs-delta vs the jnp oracle)
plus the cohort_scaling suite's (chunked vs dense round time, params delta
and executable peak MB, DESIGN.md §11), the fleet_speedup records
(DESIGN.md §12), the async_speedup record (async-vs-sync event-clock
wall at matched loss, DESIGN.md §13) and the serve_* records (hot-swapped
snapshot decode vs the client-view tree, DESIGN.md §14) — the file the CI
perf gate (``benchmarks.perf_gate``) diffs against the committed baseline
``benchmarks/baselines/BENCH_kernels.json``.

An explicitly requested roofline suite (``--only roofline``) with no
dry-run records exits non-zero instead of green-lighting an empty table;
in a combined run the empty suite emits an explicit SKIPPED row.

Schedule/transport/downlink suites build their trainers through the
declarative ``ExperimentSpec`` front door (``repro.api.build``) — the spec
is the benchmark configuration, not hand-assembled trainer wiring
(DESIGN.md §9; see ``schedules_bench._task_spec``).
"""
import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 4 paper tasks, more rounds")
    ap.add_argument("--only", default=None,
                    help="substring filter: fig12|table4|roofline|kern|"
                         "cohort|fleet|async|serve")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the kern suite's machine-readable records "
                         "(perf-gate input) to this file")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    verbose = not args.quiet

    from benchmarks import (async_bench, cohort_bench, fleet_bench,
                            kernels_bench, roofline_bench, schedules_bench,
                            serve_bench, table4_bench)

    # --only roofline is an explicit ask: an empty table must fail loudly,
    # not pass silently (the CI-green-on-no-data failure mode)
    roofline_strict = bool(args.only and "roofline" in args.only)

    kern_records = []
    cohort_records = []
    fleet_records = []
    async_records = []
    serve_records = []

    def run_kern():
        kern_records.extend(kernels_bench.run_records())
        return kernels_bench.run(verbose=verbose, records=kern_records)

    def run_cohort():
        cohort_records.extend(cohort_bench.run_records())
        return cohort_bench.run(verbose=verbose, records=cohort_records)

    def run_fleet_suite():
        fleet_records.extend(fleet_bench.run_records())
        return fleet_bench.run(verbose=verbose, records=fleet_records)

    def run_async_suite():
        async_records.extend(async_bench.run_records())
        return async_bench.run(verbose=verbose, records=async_records)

    def run_serve_suite():
        serve_records.extend(serve_bench.run_records())
        return serve_bench.run(verbose=verbose, records=serve_records)

    suites = []
    if not args.only or "table4" in args.only:
        suites.append(("table4", lambda: table4_bench.run(verbose=verbose)))
    if not args.only or "fig12" in args.only:
        tasks = (("sent140", "femnist", "cifar100", "shakespeare")
                 if args.full else ("sent140", "femnist"))
        rounds = 120 if args.full else None
        suites.append(("fig12", lambda: schedules_bench.run(
            tasks=tasks, rounds=rounds, verbose=verbose)))
    if not args.only or "roofline" in args.only:
        suites.append(("roofline", lambda: roofline_bench.run(
            verbose=verbose, strict=roofline_strict)))
    if not args.only or "kern" in args.only:
        suites.append(("kern", run_kern))
    if not args.only or "cohort" in args.only:
        suites.append(("cohort", run_cohort))
    if not args.only or "fleet" in args.only:
        suites.append(("fleet", run_fleet_suite))
    if not args.only or "async" in args.only:
        suites.append(("async", run_async_suite))
    if not args.only or "serve" in args.only:
        suites.append(("serve", run_serve_suite))

    rows = []
    for name, fn in suites:
        if verbose:
            print(f"== {name} ==", flush=True)
        rows.extend(fn())

    print("\nname,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")

    if args.json:
        gate_records = (kern_records + cohort_records + fleet_records
                        + async_records + serve_records)
        if not gate_records:
            print(f"--json {args.json}: no gate suite "
                  f"(kern/cohort/fleet/async/serve) ran (check --only "
                  f"filter)", file=sys.stderr)
            sys.exit(1)
        import jax
        payload = {"jax": jax.__version__,
                   "backend": jax.default_backend(),
                   "records": gate_records}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        if verbose:
            print(f"wrote {len(gate_records)} gate records to {args.json}")


if __name__ == "__main__":
    main()
