"""Fleet-driver records for the CI perf gate (DESIGN.md §12).

The fleet's contract is "compile once, dispatch N, pack the device": a
4-point ``fed.k0`` sweep whose points share one bucket signature (via the
fleet's ``fed.k_grid0`` anchor) must reuse a single executable across all
points AND beat running the same points serially. Two gated records in the
kernel-record schema (``kernel_us``/``oracle_us``/``max_abs_delta``) so
``benchmarks.perf_gate`` applies its machine-robust ratio/delta checks:

  * ``fleet_speedup`` — packed-concurrent fleet wall clock vs serial runs
    of the same points (each serial run a fresh build + private registry,
    i.e. the pre-fleet workflow); the gate's ratio check fails if packing
    stops being faster by more than the allowed factor. ``max_abs_delta``
    is the worst per-point params divergence packed vs serial — packing
    must not change what any point trains (0.0: same program, same
    inputs, same device).
  * ``fleet_speedup_shared_compiles`` — fleet-wide distinct compiles vs a
    single point's compile count. 100% cross-point reuse means the fleet
    compiles exactly what ONE run compiles; ``max_abs_delta`` is the
    excess-compile count, so even one extra compile trips the gate's
    delta floor.
"""
from __future__ import annotations

import time
from typing import List, Tuple

ROUNDS = 2
#: four k0 values inside one quantize bucket once the fleet pins
#: k_grid0=16 (grid step 1.35: k0 in (11.85, 16] all snap to K=16) —
#: the sweep shares ONE bucket signature across all points
SWEEP = ["fed.k0=12,14,15,16"]


def _base():
    """Reduced-LM base: a transformer whose XLA compile dominates a short
    run — the regime the fleet exists for (sweep warm-up cost, not steady
    state). 2 rounds in one bucket = 1 dispatch per point; tiny
    cohort/batch/seq keep the dispatch cheap next to the compile."""
    from repro.api import ExperimentSpec
    return ExperimentSpec().with_overrides(
        "data.kind=lm", "model.arch=qwen1.5-0.5b", "model.reduced=true",
        "data.clients=4", "data.samples_per_client=4", "data.seq_len=16",
        "data.seed=0", "fed.clients_per_round=2", f"fed.rounds={ROUNDS}",
        "fed.eta0=0.05", "fed.batch_size=2", "fed.k_schedule=fixed",
        "fed.bucket_rounds=2", "fed.eval_every=0", "fed.seed=0")


def _serial_runs(points):
    """The pre-fleet workflow: each point builds and runs on its own, with
    a private registry — every point pays its own compiles."""
    from repro.api import build
    out = []
    t0 = time.perf_counter()
    for p in points:
        exp = build(p.spec)
        exp.run()
        out.append(exp)
    return out, time.perf_counter() - t0


def run_records() -> List[dict]:
    from repro.api import expand_sweep
    from repro.launch.fleet import run_fleet, share_k_grid

    points = share_k_grid(expand_sweep(*SWEEP, base=_base()))
    # packed fleet (shared registry + backend slices)
    fleet = run_fleet(points=points, packed=True, verbose=False)
    # single-point reference: what ONE run compiles
    single = run_fleet(points=points[:1], packed=False, verbose=False)
    # serial baseline on the SAME points, fresh builds (own compiles each)
    serial_exps, serial_s = _serial_runs(points)

    # per-point divergence packed vs serial: the final train loss is a
    # deterministic f32 function of the trained params, so identical runs
    # give exactly 0.0 — any drift from packing trips the gate's floor
    by_label = {p.label: p for p in fleet.points}
    div = 0.0
    for p, exp in zip(points, serial_exps):
        div = max(div, abs(by_label[p.label].final_loss
                           - float(exp.history.train_loss[-1])))
    excess = fleet.compile_count - single.compile_count
    return [
        {"name": "fleet_speedup",
         "kernel_us": fleet.wall_s * 1e6, "oracle_us": serial_s * 1e6,
         "max_abs_delta": div},
        {"name": "fleet_speedup_shared_compiles",
         "kernel_us": float(fleet.compile_count),
         "oracle_us": float(max(single.compile_count, 1)),
         "max_abs_delta": float(max(excess, 0))},
    ]


def rows_from_records(recs: List[dict]) -> List[Tuple[str, float, str]]:
    return [(r["name"], r["kernel_us"],
             f"oracle_us={r['oracle_us']:.1f};"
             f"ratio={r['kernel_us'] / r['oracle_us']:.3f};"
             f"max_abs_delta={r['max_abs_delta']:.3g}")
            for r in recs]


def run(verbose=True, records: List[dict] = None
        ) -> List[Tuple[str, float, str]]:
    rows = rows_from_records(records if records is not None
                             else run_records())
    if verbose:
        for n, us, d in rows:
            print(f"  {n:32s} {us:12.0f}us  {d}")
    return rows
