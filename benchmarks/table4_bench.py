"""Table 4 exact reproduction: total SGD steps of each K-decay schedule
relative to K-eta-fixed over the paper's full 10k rounds.

The K_r-rounds column is fully deterministic (Eq. 10) and reproduces the
paper's numbers analytically; error/step columns depend on the loss/val
trajectory, so we report the deterministic bound from the quick simulation.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.configs import PAPER_TASKS
from repro.configs.base import FedConfig
from repro.core import RuntimeModel
from repro.core.schedules import schedule_preview

ROUNDS = 10_000

# the paper's Table 4 K_r-rounds column for reference
PAPER_TABLE4_ROUNDS = {"sent140": 0.21, "femnist": 0.11, "cifar100": 0.090,
                       "shakespeare": 0.74}


def relative_steps_equal_rounds(k0: int, rounds: int = ROUNDS) -> float:
    ks = schedule_preview(FedConfig(k0=k0, k_schedule="rounds"), rounds)
    return float(np.sum(ks)) / (k0 * rounds)


def relative_steps_equal_wallclock(task) -> float:
    """Table 4's actual accounting (reverse-engineered; see EXPERIMENTS.md):
    both schedules run for the SAME wall-clock budget — the time fixed-K
    needs for 10k rounds (the Fig. 1/2 x-axis) — so the cheaper decayed
    rounds let K_r-rounds complete far more of them. Slow-compute tasks
    (Shakespeare, beta=1.5s) therefore save little relative compute, exactly
    as the paper reports (0.74 vs 0.09 for CIFAR100)."""
    rt = RuntimeModel(task.model_size_mb, task.runtime,
                      task.fed.clients_per_round)
    k0 = task.fed.k0
    budget = rt.total_time([k0] * ROUNDS)
    comm = rt.comm_time()
    beta = task.runtime.beta_seconds
    # stream rounds of the decayed schedule until the budget is spent
    ks = schedule_preview(FedConfig(k0=k0, k_schedule="rounds"), 2_000_000)
    t, steps = 0.0, 0
    for k in ks:
        t += comm + beta * k
        if t > budget:
            break
        steps += k
    return steps / (k0 * ROUNDS)


def run(verbose=True) -> List[Tuple[str, float, str]]:
    rows = []
    for name, task in PAPER_TASKS.items():
        rel_r = relative_steps_equal_rounds(task.fed.k0)
        rel_w = relative_steps_equal_wallclock(task)
        paper = PAPER_TABLE4_ROUNDS[name]
        rows.append((f"table4_{name}_Kr-rounds", 0.0,
                     f"relsteps_equalW={rel_w:.3f};paper={paper:.3f};"
                     f"relsteps_equalR={rel_r:.3f}"))
        if verbose:
            print(f"  table4 {name:12s} K_r-rounds rel_steps(equal-time)="
                  f"{rel_w:.3f} (paper: {paper:.3f}); equal-rounds={rel_r:.3f}")
        rt = RuntimeModel(task.model_size_mb, task.runtime,
                          task.fed.clients_per_round)
        ks_fixed = [task.fed.k0] * ROUNDS
        ks_dec = schedule_preview(FedConfig(k0=task.fed.k0,
                                            k_schedule="rounds"), ROUNDS)
        speedup = rt.total_time(ks_fixed) / rt.total_time(ks_dec)
        rows.append((f"table4_{name}_wallclock_speedup", 0.0,
                     f"speedup={speedup:.2f}x"))
        if verbose:
            print(f"  table4 {name:12s} Eq.5 equal-rounds wall-clock speedup "
                  f"{speedup:.2f}x over fixed-K")
        # decayed K shrinks the compute term, so the fixed |x|/U uplink is
        # what bounds the round — int8 transport attacks exactly that term.
        # Nominal 4x codec ratio (per-leaf scale overhead vanishes at the
        # Table 1/2 model sizes; see DESIGN.md §8).
        from repro.core.engine.transport import Int8Transport
        rt8 = RuntimeModel(task.model_size_mb, task.runtime,
                           task.fed.clients_per_round,
                           uplink_compression=Int8Transport().nominal_ratio())
        speedup8 = rt.total_time(ks_fixed) / rt8.total_time(ks_dec)
        up_frac = (rt8.uplink_mbit_per_client / rt8.cfg.upload_mbps) \
            / rt8.comm_time()
        rows.append((f"table4_{name}_wallclock_speedup_int8", 0.0,
                     f"speedup={speedup8:.2f}x;"
                     f"vs_plain={speedup8 / speedup:.2f}x;"
                     f"uplink_comm_frac={up_frac:.2f}"))
        if verbose:
            print(f"  table4 {name:12s} K_r-rounds + int8 uplink: "
                  f"{speedup8:.2f}x over fixed-K uncompressed "
                  f"({speedup8 / speedup:.2f}x from the wire)")
    return rows
