"""CI perf gate over the kernel benchmark records (DESIGN.md §10.5).

    PYTHONPATH=src python -m benchmarks.perf_gate \\
        --current BENCH_kernels.json \\
        --baseline benchmarks/baselines/BENCH_kernels.json

Compares the current ``benchmarks.run --only kern --json`` output against
the committed baseline and fails (exit 1, loud per-row messages) when a
wire-path kernel regresses. Two checks per gated row:

  * **correctness** — ``max_abs_delta`` vs the jnp oracle must stay within
    ``max(delta_factor * baseline_delta, delta_floor)``. Tight: a numerics
    regression in the fused decompress-reduce / scatter kernels is the
    thing this gate exists to catch.
  * **timing** — the kernel/oracle wall-time *ratio* must stay within
    ``ratio_factor`` of the baseline ratio. Ratios, not microseconds: CI
    runners differ in absolute speed but kernel and oracle shift together,
    so the ratio is machine-robust; the generous factor absorbs scheduler
    noise while still catching order-of-magnitude regressions (e.g. a
    fused kernel silently falling back to a dense path).

Only wire-path rows (fedavg reduce, int8 delta reduce, top-k scatter) and
the cohort_scaling rows (chunked-vs-dense round equivalence, DESIGN.md §11)
are gated — attention/SSD/MoE rows have no oracle contract here. A gated row
missing from the current records is itself a failure: silently dropping a
kernel from the bench must not turn the gate green.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

#: rows the gate enforces (name prefixes). cohort_scaling rows reuse the
#: schema for the chunked-streaming contract (DESIGN.md §11): "kernel" is
#: the chunked round (time / peak MB), "oracle" the dense round, and the
#: delta is the params divergence — so the same ratio/delta checks gate a
#: chunked path that slows down, diverges, or rematerialises the cohort.
#: fleet_speedup rows reuse it for the fleet contract (DESIGN.md §12):
#: "kernel" is the packed fleet (wall / compile count), "oracle" the serial
#: baseline (wall / single-run compiles), and the delta is the per-point
#: divergence (loss drift / excess compiles) — so packing that slows down,
#: changes results, or stops sharing executables trips the same checks.
#: async_speedup rows reuse it for the async contract (DESIGN.md §13):
#: "kernel" is the async event-clock wall at the sync run's matched final
#: loss, "oracle" the sync wall, and the delta the relative loss gap — so
#: an async engine that stops out-pacing the straggler-bound sync round
#: (or stops converging to the same loss) trips the same checks.
#: serve_* rows reuse it for the serving contract (DESIGN.md §14):
#: "kernel" is the live ServingLoop (µs/token served / hot-swap latency),
#: "oracle" the same decode / reconstruction driven directly with the
#: client-view tree, and the delta the served-vs-client divergence
#: (generated-id gap / leafwise snapshot gap, 0 by the snapshot contract)
#: — so a snapshot that drifts from what clients hold, or a swap path that
#: starts copying extra state, trips the same checks.
GATED_PREFIXES = ("kern_fedavg_reduce", "kern_int8_delta_reduce",
                  "kern_topk_scatter", "cohort_scaling", "fleet_speedup",
                  "async_speedup", "serve_tokens_per_sec", "serve_swap_us")

#: timing: current kernel/oracle ratio may be at most this factor above the
#: baseline ratio (floored — tiny baseline ratios would gate on noise)
RATIO_FACTOR = 4.0
RATIO_FLOOR = 0.05

#: correctness: current delta may be at most max(factor * baseline, floor)
DELTA_FACTOR = 2.0
DELTA_FLOOR = 1e-4


def load_records(path: str) -> List[dict]:
    with open(path) as f:
        data = json.load(f)
    return data["records"] if isinstance(data, dict) else data


def check(current: List[dict], baseline: List[dict], *,
          ratio_factor: float = RATIO_FACTOR,
          delta_factor: float = DELTA_FACTOR,
          delta_floor: float = DELTA_FLOOR) -> List[str]:
    """Returns human-readable failure messages; empty list == gate passes."""
    failures: List[str] = []
    cur = {r["name"]: r for r in current}
    for b in baseline:
        name = b["name"]
        if not name.startswith(GATED_PREFIXES):
            continue
        c = cur.get(name)
        if c is None:
            failures.append(f"{name}: gated kernel missing from current "
                            f"records")
            continue
        limit = max(delta_factor * (b.get("max_abs_delta") or 0.0),
                    delta_floor)
        d = c.get("max_abs_delta")
        if d is None or d > limit:
            failures.append(f"{name}: max_abs_delta {d} exceeds {limit:.3g} "
                            f"(baseline {b.get('max_abs_delta')})")
        if b.get("oracle_us") and c.get("oracle_us"):
            base_ratio = b["kernel_us"] / b["oracle_us"]
            cur_ratio = c["kernel_us"] / c["oracle_us"]
            limit = ratio_factor * max(base_ratio, RATIO_FLOOR)
            if cur_ratio > limit:
                failures.append(
                    f"{name}: kernel/oracle time ratio {cur_ratio:.3f} "
                    f"exceeds {limit:.3f} (baseline {base_ratio:.3f} "
                    f"x factor {ratio_factor})")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True,
                    help="BENCH_kernels.json from this run "
                         "(benchmarks.run --only kern --json)")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_kernels.json",
                    help="committed baseline records")
    ap.add_argument("--ratio-factor", type=float, default=RATIO_FACTOR)
    args = ap.parse_args(argv)
    failures = check(load_records(args.current),
                     load_records(args.baseline),
                     ratio_factor=args.ratio_factor)
    if failures:
        print("perf gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("perf gate passed")


if __name__ == "__main__":
    main()
