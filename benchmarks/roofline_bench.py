"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and per-chip memory.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional, Tuple

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(dirname: Optional[str] = None) -> List[dict]:
    files = sorted(glob.glob(os.path.join(dirname or DRYRUN_DIR, "*.json")))
    return [json.load(open(f)) for f in files]


def format_row(r: dict) -> str:
    t = r["roofline"]
    mem = r.get("memory_analysis", {})
    gb = (mem.get("temp_size_in_bytes", 0)
          + mem.get("argument_size_in_bytes", 0)) / 1e9
    ratio = r.get("useful_flops_ratio")
    return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"comp={t['compute_s']:9.3e}s mem={t['memory_s']:9.3e}s "
            f"coll={t['collective_s']:9.3e}s dom={t['dominant']:10s} "
            f"6ND/HLO={ratio if ratio is None else round(ratio, 3)!s:6s} "
            f"hbm={gb:6.1f}GB")


def run(verbose=True, strict=False,
        dirname: Optional[str] = None) -> List[Tuple[str, float, str]]:
    """``strict``: an empty record set is an error (SystemExit) instead of
    a quietly-green empty table — used when the roofline suite was asked
    for explicitly. Non-strict runs still emit an explicit SKIPPED row so
    the absence is visible in the output, never silent."""
    rows = []
    recs = load_records(dirname)
    if not recs:
        print("  (no dry-run records found — run "
              "`python -m repro.launch.dryrun --all` first)")
        if strict:
            raise SystemExit("roofline: no dry-run records under "
                             f"{dirname or DRYRUN_DIR} — refusing to "
                             "report an empty roofline as success")
        rows.append(("roofline_all", 0.0, "SKIPPED:no-dryrun-records"))
        return rows
    for r in recs:
        if r["status"] == "skipped":
            rows.append((f"roofline_{r['case']}", 0.0, "skipped:" +
                         r["reason"].split(":")[0]))
            continue
        if r["status"] != "ok":
            rows.append((f"roofline_{r['case']}", 0.0, "ERROR"))
            continue
        t = r["roofline"]
        rows.append((f"roofline_{r['case']}", r.get("compile_s", 0) * 1e6,
                     f"dom={t['dominant']};comp={t['compute_s']:.3e};"
                     f"mem={t['memory_s']:.3e};coll={t['collective_s']:.3e}"))
        if verbose:
            print("  " + format_row(r))
    return rows
