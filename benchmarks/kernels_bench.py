"""Kernel micro-benchmarks: interpret-mode Pallas vs jnp oracle wall-time.

On CPU the interpret path is NOT indicative of TPU speed — the number that
matters offline is the allclose delta (correctness) and the kernel/oracle
timing *ratio* (a machine-robust reference point across commits; absolute
microseconds shift with the runner). Lowered-TPU timing lands when hardware
is available.

``run_records()`` is the machine-readable entry point the CI perf gate
consumes (``benchmarks.perf_gate``): one dict per kernel with ``kernel_us``,
``oracle_us`` and ``max_abs_delta`` against the jnp oracle. ``run()`` keeps
the historical printed-row contract on top of it.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import delta_codec, ops, ref


def _time(f, *args, iters=3) -> float:
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _delta(a, b) -> float:
    return float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                 - jnp.asarray(b, jnp.float32))))


def _topk_payload(key, n: int, k: int, m: int):
    """A stacked top-k payload with duplicate indices across clients (the
    scatter's accumulate path is exercised, not just the gather)."""
    kv, ki, kw = jax.random.split(key, 3)
    vals = jax.random.normal(kv, (n, k))
    idx = jax.random.randint(ki, (n, k), 0, m).astype(jnp.int32)
    weights = jax.nn.softmax(jax.random.normal(kw, (n,)))
    return vals, idx, weights


def _dense_scatter_oracle(vals, idx, weights, size):
    """The one-hot-matmul formulation in plain jnp (DESIGN.md §10.1) — the
    dense oracle both scatter implementations must match."""
    contrib = (vals.astype(jnp.float32)
               * weights.astype(jnp.float32)[:, None]).reshape(-1)
    oh = (idx.reshape(-1)[:, None] == jnp.arange(size)[None, :])
    return contrib @ oh.astype(jnp.float32)


def run_records() -> List[dict]:
    recs = []
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    x = jax.random.normal(ks[0], (16, 1 << 16))
    w = jax.nn.softmax(jax.random.normal(ks[1], (16,)))
    oracle = jax.jit(ref.fedavg_reduce_ref)
    recs.append({"name": "kern_fedavg_reduce",
                 "kernel_us": _time(ops.fedavg_reduce, x, w),
                 "oracle_us": _time(oracle, x, w),
                 "max_abs_delta": _delta(ops.fedavg_reduce(x, w),
                                         oracle(x, w))})

    # fused int8 decompress-reduce (transport, DESIGN.md §8): oracle is
    # decode-to-f32 then the weighted einsum — the (N, M) f32 materialise
    # the fused kernel avoids
    qi = jnp.clip(jnp.round(x * 40.0), -127, 127).astype(jnp.int8)
    qr = jnp.clip(jnp.round((x - qi * 0.025) * 5080.0), -127, 127
                  ).astype(jnp.int8)
    w1, w2 = w * 0.025, w * (0.025 / 127.0)
    oracle = jax.jit(lambda q, qr, w1, w2: jnp.einsum(
        "c,cm->m", w1, q.astype(jnp.float32))
        + jnp.einsum("c,cm->m", w2, qr.astype(jnp.float32)))
    recs.append({"name": "kern_int8_delta_reduce",
                 "kernel_us": _time(ops.int8_delta_reduce, qi, w1, qr, w2),
                 "oracle_us": _time(oracle, qi, qr, w1, w2),
                 "max_abs_delta": _delta(
                     ops.int8_delta_reduce(qi, w1, qr, w2),
                     oracle(qi, qr, w1, w2))})

    # top-k scatter-reduce/apply (DESIGN.md §10.1): XLA segment-scatter vs
    # the Mosaic one-hot-matmul kernel, both against the dense-matmul
    # oracle — duplicate indices included so accumulation is covered
    n, k, m = 8, 128, 4096
    vals, idx, weights = _topk_payload(ks[2], n, k, m)
    dense = jax.jit(_dense_scatter_oracle, static_argnums=3)
    want = dense(vals, idx, weights, m)
    us_dense = _time(lambda v, i, w: dense(v, i, w, m), vals, idx, weights)
    xla = jax.jit(lambda v, i, w: delta_codec.topk_scatter_reduce(
        v, i, w, m))
    mosaic = jax.jit(lambda v, i, w: delta_codec.topk_scatter_reduce_mosaic(
        v, i, w, m, interpret=ops.INTERPRET))
    recs.append({"name": "kern_topk_scatter_reduce_xla",
                 "kernel_us": _time(xla, vals, idx, weights),
                 "oracle_us": us_dense,
                 "max_abs_delta": _delta(xla(vals, idx, weights), want)})
    recs.append({"name": "kern_topk_scatter_reduce_mosaic",
                 "kernel_us": _time(mosaic, vals, idx, weights),
                 "oracle_us": us_dense,
                 "max_abs_delta": _delta(mosaic(vals, idx, weights), want)})

    refv = jax.random.normal(ks[3], (m,))
    v1, i1 = vals[0], idx[0]
    apply_want = refv.at[i1].add(v1)     # XLA scatter-add == dense apply
    xla_a = jax.jit(delta_codec.topk_scatter_apply)
    mosaic_a = jax.jit(lambda r, v, i: delta_codec.topk_scatter_apply_mosaic(
        r, v, i, interpret=ops.INTERPRET))
    us_oracle = _time(lambda r, v, i: r.at[i].add(v), refv, v1, i1)
    recs.append({"name": "kern_topk_scatter_apply_xla",
                 "kernel_us": _time(xla_a, refv, v1, i1),
                 "oracle_us": us_oracle,
                 "max_abs_delta": _delta(xla_a(refv, v1, i1), apply_want)})
    recs.append({"name": "kern_topk_scatter_apply_mosaic",
                 "kernel_us": _time(mosaic_a, refv, v1, i1),
                 "oracle_us": us_oracle,
                 "max_abs_delta": _delta(mosaic_a(refv, v1, i1),
                                         apply_want)})

    q = jax.random.normal(ks[0], (1, 512, 8, 64)) * 0.3
    kk = jax.random.normal(ks[1], (1, 512, 2, 64)) * 0.3
    v = jax.random.normal(ks[2], (1, 512, 2, 64))
    recs.append({"name": "kern_flash_attention",
                 "kernel_us": _time(lambda q: ops.flash_attention(q, kk, v),
                                    q),
                 "oracle_us": None, "max_abs_delta": None})

    xs = jax.random.normal(ks[0], (2, 512, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 512, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    b = jax.random.normal(ks[3], (2, 512, 32)) * 0.5
    recs.append({"name": "kern_ssd_scan",
                 "kernel_us": _time(
                     lambda x: ops.ssd_scan(x, dt, A, b, b, jnp.ones(4))[0],
                     xs),
                 "oracle_us": None, "max_abs_delta": None})

    xe = jax.random.normal(ks[0], (8, 256, 512)) * 0.1
    we = jax.random.normal(ks[1], (8, 512, 1024)) * 0.05
    oracle = jax.jit(ref.gmm_ref)
    recs.append({"name": "kern_moe_gmm",
                 "kernel_us": _time(ops.gmm, xe, we),
                 "oracle_us": _time(oracle, xe, we),
                 "max_abs_delta": _delta(ops.gmm(xe, we), oracle(xe, we))})
    return recs


def rows_from_records(recs: List[dict]) -> List[Tuple[str, float, str]]:
    rows = []
    for r in recs:
        if r["oracle_us"] is None:
            derived = "interpret"
        else:
            derived = (f"oracle_us={r['oracle_us']:.0f};"
                       f"delta={r['max_abs_delta']:.2e}")
        rows.append((r["name"], r["kernel_us"], derived))
    return rows


def run(verbose=True, records: List[dict] = None
        ) -> List[Tuple[str, float, str]]:
    rows = rows_from_records(records if records is not None
                             else run_records())
    if verbose:
        for n, us, d in rows:
            print(f"  {n:32s} {us:12.0f}us  {d}")
    return rows
