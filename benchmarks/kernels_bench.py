"""Kernel micro-benchmarks: interpret-mode Pallas vs jnp oracle wall-time.

On CPU the interpret path is NOT indicative of TPU speed — the number that
matters offline is the allclose delta (correctness) and the oracle time (a
stable reference point across commits). Lowered-TPU timing lands when
hardware is available.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, iters=3) -> float:
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose=True) -> List[Tuple[str, float, str]]:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    x = jax.random.normal(ks[0], (16, 1 << 16))
    w = jax.nn.softmax(jax.random.normal(ks[1], (16,)))
    us_k = _time(ops.fedavg_reduce, x, w)
    us_r = _time(jax.jit(ref.fedavg_reduce_ref), x, w)
    rows.append(("kern_fedavg_reduce", us_k, f"oracle_us={us_r:.0f}"))

    # fused int8 decompress-reduce (transport, DESIGN.md §8): oracle is
    # decode-to-f32 then the weighted einsum — the (N, M) f32 materialise
    # the fused kernel avoids
    qi = jnp.clip(jnp.round(x * 40.0), -127, 127).astype(jnp.int8)
    qr = jnp.clip(jnp.round((x - qi * 0.025) * 5080.0), -127, 127
                  ).astype(jnp.int8)
    w1, w2 = w * 0.025, w * (0.025 / 127.0)
    us_k = _time(ops.int8_delta_reduce, qi, w1, qr, w2)
    oracle = jax.jit(lambda q, qr, w1, w2: jnp.einsum(
        "c,cm->m", w1, q.astype(jnp.float32))
        + jnp.einsum("c,cm->m", w2, qr.astype(jnp.float32)))
    us_r = _time(oracle, qi, qr, w1, w2)
    rows.append(("kern_int8_delta_reduce", us_k, f"oracle_us={us_r:.0f}"))

    q = jax.random.normal(ks[0], (1, 512, 8, 64)) * 0.3
    k = jax.random.normal(ks[1], (1, 512, 2, 64)) * 0.3
    v = jax.random.normal(ks[2], (1, 512, 2, 64))
    us_k = _time(lambda q: ops.flash_attention(q, k, v), q)
    rows.append(("kern_flash_attention", us_k, "interpret"))

    xs = jax.random.normal(ks[0], (2, 512, 4, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 512, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    b = jax.random.normal(ks[3], (2, 512, 32)) * 0.5
    us_k = _time(lambda x: ops.ssd_scan(x, dt, A, b, b, jnp.ones(4))[0], xs)
    rows.append(("kern_ssd_scan", us_k, "interpret"))

    xe = jax.random.normal(ks[0], (8, 256, 512)) * 0.1
    we = jax.random.normal(ks[1], (8, 512, 1024)) * 0.05
    us_k = _time(ops.gmm, xe, we)
    us_r = _time(jax.jit(ref.gmm_ref), xe, we)
    rows.append(("kern_moe_gmm", us_k, f"oracle_us={us_r:.0f}"))

    if verbose:
        for n, us, d in rows:
            print(f"  {n:24s} {us:12.0f}us  {d}")
    return rows
