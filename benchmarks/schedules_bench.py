"""Fig. 1 / Fig. 2 / Table 4 analogue: FedAvg schedule comparison.

Runs every schedule of Table 3 on synthetic non-IID versions of the paper's
tasks under the paper's runtime model (Eq. 5, Table 1/2 constants), and
reports: min training loss within the time budget (Fig. 1), best validation
accuracy (Fig. 2), and SGD steps relative to K-eta-fixed (Table 4).

Also benchmarks the K-bucketed round engine against the seed per-round loop
(``engine_*`` rows): real rounds/sec speedup and compile count vs. the
K-quantization grid bound (DESIGN.md §6.4).

Schedule, transport and downlink rows construct their trainers through the
declarative ``ExperimentSpec`` front door (``build(spec)``), not hand-built
``FedConfig``/``FedAvgTrainer`` wiring — the spec is the configuration
artifact (ROADMAP: benchmarks stop hand-building trainers). The engine
speedup/backend rows still construct directly: they measure engine
internals (the seed parity oracle, injected backends) the facade
deliberately does not expose.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.api import ExperimentSpec, build
from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import (FedAvgTrainer, RuntimeModel, make_eval_fn,
                        quantize_k, run_reference_rounds)
from repro.data import make_paper_task
from repro.models import small

SCHEDULES = [
    ("dsgd", "dsgd", "fixed"),
    ("K-eta-fixed", "fixed", "fixed"),
    ("K_r-rounds", "rounds", "fixed"),
    ("K_r-error", "error", "fixed"),
    ("K_r-step", "step", "fixed"),
    ("eta_r-rounds", "fixed", "rounds"),
    ("eta_r-error", "fixed", "error"),
    ("eta_r-step", "fixed", "step"),
]

# CPU-scale round counts (the harness takes --rounds for full runs)
QUICK = dict(rounds=40, clients=30, per_round=8, k0=10, samples=30)


def _task_spec(task_name: str, rounds: int, seed: int) -> ExperimentSpec:
    """The CPU-scale paper-task base spec (QUICK knobs + the task's own
    Table 1/2 runtime constants and eta0, exactly what the hand-built
    ``FedConfig``/``RuntimeModel`` wiring used to assemble)."""
    task = get_paper_task(task_name)
    rt = task.runtime
    return ExperimentSpec().with_overrides(
        "data.kind=paper", f"data.task={task_name}",
        f"data.clients={QUICK['clients']}",
        f"data.samples_per_client={QUICK['samples']}", f"data.seed={seed}",
        f"fed.clients_per_round={QUICK['per_round']}", f"fed.rounds={rounds}",
        f"fed.k0={QUICK['k0']}", f"fed.eta0={task.fed.eta0}",
        f"fed.batch_size={min(task.fed.batch_size, 16)}",
        f"fed.loss_window={max(rounds // 8, 3)}", f"fed.seed={seed}",
        f"runtime.download_mbps={rt.download_mbps}",
        f"runtime.upload_mbps={rt.upload_mbps}",
        f"runtime.beta_seconds={rt.beta_seconds}")


def run_task(task_name: str, rounds: int, *, seed: int = 0,
             verbose: bool = False) -> List[Dict]:
    results = []
    for name, ksch, esch in SCHEDULES:
        spec = _task_spec(task_name, rounds, seed).with_overrides(
            f"fed.k_schedule={ksch}", f"fed.eta_schedule={esch}",
            "fed.plateau_patience=3",
            f"fed.eval_every={max(rounds // 8, 1)}")
        exp = build(spec)      # data/param construction outside the clock
        t0 = time.time()
        h = exp.run()
        rel = h.sgd_steps[-1] / (QUICK["k0"] * rounds * QUICK["per_round"])
        results.append({
            "task": task_name, "schedule": name,
            "min_train_loss": h.min_train_loss[-1],
            "max_val_acc": h.max_val_acc[-1] if h.max_val_acc else 0.0,
            "sim_wall_clock_s": h.wall_clock_s[-1],
            "uplink_mbit": h.uplink_mbit[-1],
            "downlink_mbit": h.downlink_mbit[-1],
            "relative_sgd_steps": rel,
            "bench_s": time.time() - t0,
        })
        if verbose:
            r = results[-1]
            print(f"  {task_name:12s} {name:12s} loss={r['min_train_loss']:.4f} "
                  f"acc={r['max_val_acc']:.3f} W={r['sim_wall_clock_s']:.0f}s "
                  f"rel_steps={rel:.2f} up={r['uplink_mbit']:.0f}mbit")
    return results


def run_engine_speedup(rounds: int = 200, *, task_name: str = "sent140",
                       clients_per_round: int = 4, batch_size: int = 4,
                       prefetch: bool = False, seed: int = 0,
                       verbose: bool = False) -> Dict:
    """K-bucketed engine vs. seed loop on the ``rounds`` K-decay schedule.

    The default config is the dispatch-bound regime the bucketing targets:
    small per-round payloads over a long horizon — where per-round python,
    dispatch and the seed loop's blocking per-round loss sync dominate.
    (The background prefetch thread targets the opposite, compute-bound
    regime — see ``run_prefetch_overlap`` — so it is off here.)

    Both loops run twice and the second (warm-executable) pass is timed, so
    the numbers are steady-state rounds/sec — the regime long federated runs
    live in — not XLA compile time.  Also reports the engine's compile count
    against its bound, the K-quantization grid size (DESIGN.md §6.4)."""
    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    fed = FedConfig(total_clients=data.num_clients,
                    clients_per_round=clients_per_round, rounds=rounds,
                    k0=QUICK["k0"], eta0=task.fed.eta0,
                    batch_size=batch_size, k_schedule="rounds",
                    k_quantize=True, prefetch=prefetch, seed=seed)
    grid = len({quantize_k(k, fed.k0) for k in range(1, fed.k0 + 1)})
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)

    ref = run_reference_rounds(loss_fn, params0, data, fed, rounds)  # warm-up
    seed_compiles = len(set(ref.ks))
    t0 = time.time()
    run_reference_rounds(loss_fn, params0, data, fed, rounds,
                         round_fn=ref.round_fn)
    seed_s = time.time() - t0

    rt = RuntimeModel(task.model_size_mb, task.runtime, fed.clients_per_round)
    tr = FedAvgTrainer(loss_fn, params0, data, fed, rt)
    tr.run(rounds)                                                  # warm-up
    t0 = time.time()
    tr.run(rounds)     # loss-free schedule: identical K trajectory, warm jit
    engine_s = time.time() - t0

    out = {"rounds": rounds, "seed_s": seed_s, "engine_s": engine_s,
           "speedup": seed_s / engine_s,
           "seed_rps": rounds / seed_s, "engine_rps": rounds / engine_s,
           "compile_count": tr.compile_count, "seed_compiles": seed_compiles,
           "k_grid_size": grid}
    if verbose:
        print(f"  engine_bucketed[{task_name}]: {out['engine_rps']:.1f} "
              f"rounds/s vs seed {out['seed_rps']:.1f} rounds/s "
              f"({out['speedup']:.2f}x); compiles {out['compile_count']} <= "
              f"grid {grid} (seed loop: {seed_compiles})")
    return out


def run_backend_compare(rounds: int = 60, *, task_name: str = "sent140",
                        clients_per_round: int = 4, batch_size: int = 4,
                        seed: int = 0, verbose: bool = False) -> List[Dict]:
    """Local vs mesh ExecutionBackend on the same K-decay run (DESIGN.md §7).

    Both backends drive the identical FedAvgTrainer/K-bucketed scan; the
    mesh rows run on the host-device (devices x 1) data x model mesh —
    degenerate on 1 CPU device, but the same GSPMD/jit path a pod takes.
    Reports warm rounds/sec plus dispatch and compile counts, so the
    K-bucket amortisation (dispatches << rounds) is visible on both paths.
    """
    from repro.core.engine import MeshBackend

    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, clients_per_round)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    backends = [
        ("local", lambda: None),
        ("mesh_parallel", lambda: MeshBackend(mesh, strategy="parallel")),
        ("mesh_sequential", lambda: MeshBackend(mesh, strategy="sequential",
                                                groups=2)),
    ]
    out = []
    for name, mk in backends:
        fed = FedConfig(total_clients=data.num_clients,
                        clients_per_round=clients_per_round, rounds=rounds,
                        k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=batch_size, k_schedule="rounds",
                        k_quantize=True, seed=seed)
        tr = FedAvgTrainer(loss_fn, params0, data, fed, rt, backend=mk())
        tr.run(rounds)                                          # warm-up
        d0 = tr.engine.dispatch_count
        t0 = time.time()
        tr.run(rounds)
        dt = time.time() - t0
        row = {"backend": name, "rounds": rounds, "bench_s": dt,
               "rps": rounds / dt, "dispatches": tr.engine.dispatch_count - d0,
               "compiles": tr.compile_count}
        out.append(row)
        if verbose:
            print(f"  engine_backend[{name}]: {row['rps']:.1f} rounds/s, "
                  f"{row['dispatches']} dispatches / {rounds} rounds, "
                  f"{row['compiles']} compiles")
    return out


def run_transport_compare(rounds: int = 30, *, task_name: str = "femnist",
                          topk_frac: float = 0.05, seed: int = 0,
                          verbose: bool = False) -> List[Dict]:
    """Delta-transport codecs on the decaying-K schedule (DESIGN.md §8).

    Same task/schedule/seed per codec; reports final + min training loss
    (the 'matched final loss' contract — int8's error-feedback keeps it at
    the uncompressed loss), total modelled bytes-on-wire, the uplink
    reduction vs ``none``, and the modelled Eq. 5 wall-clock — the wire is
    a first-class axis of the decayed-K comparison now, not just FLOPs.
    Single-level int8 rides ~1.0003 bytes/param (value plane + one f32
    scale per leaf), i.e. the full 4x vs f32 up to per-leaf metadata.
    """
    out: List[Dict] = []
    for name in ("none", "int8", "topk"):
        spec = _task_spec(task_name, rounds, seed).with_overrides(
            "fed.k_schedule=rounds", "fed.k_quantize=true",
            f"transport.name={name}", f"transport.topk_frac={topk_frac}")
        exp = build(spec)      # data/param construction outside the clock
        t0 = time.time()
        h = exp.run()
        out.append({
            "transport": name, "task": task_name,
            "final_loss": h.train_loss[-1],
            "min_train_loss": h.min_train_loss[-1],
            "uplink_mbit": h.uplink_mbit[-1],
            "uplink_x": out[0]["uplink_mbit"] / h.uplink_mbit[-1]
            if out else 1.0,
            "dloss": h.train_loss[-1] - out[0]["final_loss"] if out else 0.0,
            "sim_wall_clock_s": h.wall_clock_s[-1],
            "bench_s": time.time() - t0,
        })
        if verbose:
            r = out[-1]
            print(f"  transport[{name:5s}] {task_name}: "
                  f"loss={r['final_loss']:.4f} (d={r['dloss']:+.4f}) "
                  f"uplink={r['uplink_mbit']:.0f}mbit "
                  f"({r['uplink_x']:.2f}x less) "
                  f"W={r['sim_wall_clock_s']:.0f}s")
    return out


def run_downlink_compare(rounds: int = 30, *, task_name: str = "femnist",
                         seed: int = 0, verbose: bool = False) -> List[Dict]:
    """Downlink broadcast codecs on the int8-uplink decayed-K config
    (DESIGN.md §8.6): same task/schedule/seed per row, only
    ``transport.downlink`` varies. Reports modelled downlink bytes-on-wire,
    the reduction vs the uncompressed broadcast (int8's delta-vs-reference
    payload is the full ~4x, so the ≥3x acceptance bar clears with
    metadata to spare), the Eq. 5 wall-clock, and the final-loss delta —
    the matched-final-loss contract is |dloss| <= 2% relative (the
    downlink EF residual recovers the quantisation error across rounds;
    rtol documented in DESIGN.md §8.6).

    The ``adaptive`` row exercises the per-round skip/int8/int8x2 policy
    (DESIGN.md §10) and the ``int8+q8ref`` row the quantised server-side
    reference store — ``state_mb`` reports the server bytes held for the
    broadcast state, the quantity q8 halves."""
    out: List[Dict] = []
    cases = (("none", ()), ("int8", ()), ("topk", ()), ("adaptive", ()),
             ("int8+q8ref", ("transport.ref_store=q8",)))
    for label, extra in cases:
        name = label.split("+")[0]
        spec = _task_spec(task_name, rounds, seed).with_overrides(
            "fed.k_schedule=rounds", "fed.k_quantize=true",
            "transport.name=int8", f"transport.downlink={name}", *extra)
        exp = build(spec)      # data/param construction outside the clock
        t0 = time.time()
        h = exp.run()
        dl = exp.trainer.engine.downlink
        state_mb = (dl.state_bytes(exp.trainer.engine.downlink_state) / 1e6
                    if dl is not None else 0.0)
        out.append({
            "downlink": label, "task": task_name,
            "final_loss": h.train_loss[-1],
            "min_train_loss": h.min_train_loss[-1],
            "uplink_mbit": h.uplink_mbit[-1],
            "downlink_mbit": h.downlink_mbit[-1],
            "downlink_x": out[0]["downlink_mbit"] / h.downlink_mbit[-1]
            if out and h.downlink_mbit[-1] else 1.0,
            "dloss": h.train_loss[-1] - out[0]["final_loss"] if out else 0.0,
            "state_mb": state_mb,
            "sim_wall_clock_s": h.wall_clock_s[-1],
            "bench_s": time.time() - t0,
        })
        if verbose:
            r = out[-1]
            print(f"  downlink[{label:10s}] {task_name}: "
                  f"loss={r['final_loss']:.4f} (d={r['dloss']:+.4f}) "
                  f"downlink={r['downlink_mbit']:.0f}mbit "
                  f"({r['downlink_x']:.2f}x less) "
                  f"state={r['state_mb']:.2f}MB "
                  f"W={r['sim_wall_clock_s']:.0f}s")
    return out


def run_prefetch_overlap(rounds: int = 48, *, seed: int = 0,
                         verbose: bool = False) -> Dict:
    """Background prefetch thread vs. the inline builder on a compute-bound
    config (large batches, fixed K0, periodic eval).

    Expected ≈1.0x on CPU: async dispatch already hides the depth-1 inline
    build behind the previous bucket's device work, so this row is an
    overhead check — the thread must not cost throughput.  Its value is the
    double-buffering contract for regimes where the main thread blocks
    (frequent feedback syncs, blocking dispatch) — see DESIGN.md §6.5/§6.6."""
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 8)
    eval_fn = make_eval_fn(loss_fn, data)
    trainers = {}
    for prefetch in (False, True):
        fed = FedConfig(total_clients=data.num_clients, clients_per_round=8,
                        rounds=rounds, k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=32, k_schedule="fixed",
                        prefetch=prefetch, seed=seed)
        tr = FedAvgTrainer(loss_fn, params0, data, fed, rt, eval_fn=eval_fn)
        tr.run(rounds, eval_every=8)                                # warm-up
        trainers[prefetch] = tr
    times = {False: [], True: []}
    for _ in range(3):                     # alternate legs; min vs host noise
        for prefetch in (False, True):
            t0 = time.time()
            trainers[prefetch].run(rounds, eval_every=8)
            times[prefetch].append(time.time() - t0)
    out = {"rounds": rounds, "sync_s": min(times[False]),
           "prefetch_s": min(times[True]),
           "speedup": min(times[False]) / min(times[True])}
    if verbose:
        print(f"  prefetch_overlap: {rounds / out['prefetch_s']:.1f} rounds/s "
              f"vs sync {rounds / out['sync_s']:.1f} rounds/s "
              f"({out['speedup']:.2f}x)")
    return out


def run_cohort_stream(rounds: int = 6, *, task_name: str = "femnist",
                      clients: int = 64, clients_per_round: int = 32,
                      chunk: int = 4, seed: int = 0,
                      verbose: bool = False) -> List[Dict]:
    """Streaming cohorts vs the dense round (DESIGN.md §11).

    Same task/schedule/seed; the dense row runs the whole U-client cohort
    as one executable (bucket_rounds=1 so both rows hold exactly one
    round's tensors — the comparison is round-shape vs slab-shape, not
    bucket amortisation), the chunked row streams it as U/C slabs. Rows
    report warm rounds/sec and ``peakMB`` — the engine executables' live
    device bytes (arguments + outputs + XLA temp high-water mark, measured
    from ``memory_analysis``, ``repro.core.mem``). The chunked row's peak
    must sit >= 4x under dense at U/C = 8 (the CI cohort_scaling gate rides
    the same measurement). The population row runs the identical chunked
    config against 10^6 virtual client ids (population sampler +
    PopulationView) — O(cohort) host state, same slab-bounded device peak.
    """
    from repro.core import trainer_peak_mb

    base = _task_spec(task_name, rounds, seed).with_overrides(
        f"data.clients={clients}", "fed.k_schedule=rounds",
        "fed.k_quantize=true", f"fed.clients_per_round={clients_per_round}",
        "fed.bucket_rounds=1", "fed.eval_every=0")
    cases = (
        ("dense", ()),
        (f"chunk{chunk}", (f"fed.cohort_chunk={chunk}",)),
        ("population", (f"fed.cohort_chunk={chunk}",
                        "sampler.name=population",
                        "sampler.population=1000000")),
    )
    out: List[Dict] = []
    for label, extra in cases:
        exp = build(base.with_overrides(*extra))
        exp.run()                                               # warm-up
        t0 = time.time()
        h = exp.run()
        dt = time.time() - t0
        peak = trainer_peak_mb(exp.trainer)
        out.append({
            "case": label, "task": task_name, "rounds": rounds,
            "bench_s": dt, "rps": rounds / dt, "peak_mb": peak,
            "peak_x": out[0]["peak_mb"] / peak if out and peak else 1.0,
            "final_loss": h.train_loss[-1],
        })
        if verbose:
            r = out[-1]
            print(f"  cohort_stream[{label:10s}] {task_name}: "
                  f"{r['rps']:.1f} rounds/s peak={peak:.2f}MB "
                  f"({r['peak_x']:.2f}x less) loss={r['final_loss']:.4f}")
    return out


def run_sampler_compare(rounds: int = 30, *, task_name: str = "femnist",
                        seed: int = 0, verbose: bool = False) -> List[Dict]:
    """Client-sampling policies (DESIGN.md §9.3) on one task, constructed
    through the declarative API (``build(spec)``): uniform is the paper
    baseline, weighted biases toward data-rich clients, fixed_cohort is the
    cross-silo regime (per-client EF when combined with an EF transport),
    availability simulates device churn. Rows double as a facade check —
    ``build`` must add no measurable overhead over direct construction."""
    from repro.api import ExperimentSpec, build

    base = ExperimentSpec().with_overrides(
        "data.kind=paper", f"data.task={task_name}",
        f"data.clients={QUICK['clients']}",
        f"data.samples_per_client={QUICK['samples']}", f"data.seed={seed}",
        f"fed.rounds={rounds}", "fed.clients_per_round=8",
        f"fed.k0={QUICK['k0']}", "fed.eta0=0.3", "fed.batch_size=8",
        "fed.k_schedule=rounds", "fed.loss_window=5", f"fed.seed={seed}",
        "runtime.beta_seconds=0.05")
    out = []
    for sampler, extra in (("uniform", ()),
                           ("weighted", ()),
                           ("fixed_cohort", ("transport.name=int8",)),
                           ("availability", ("sampler.availability=0.6",))):
        spec = base.with_overrides(f"sampler.name={sampler}", *extra)
        exp = build(spec)
        t0 = time.time()
        h = exp.run()
        dt = time.time() - t0
        ef = getattr(exp.trainer.engine.transport, "ef_slots", None)
        out.append({"sampler": sampler, "task": task_name, "bench_s": dt,
                    "rps": rounds / dt, "final_loss": h.train_loss[-1],
                    "ef_slots": ef or 0})
        if verbose:
            print(f"  sampler[{sampler}]: {rounds / dt:.1f} rounds/s "
                  f"loss={h.train_loss[-1]:.4f}"
                  + (f" per-client-EF x{ef}" if ef else ""))
    return out


def run(tasks=("sent140", "femnist"), rounds=None,
        verbose=True) -> List[Tuple[str, float, str]]:
    rows = []
    for t in tasks:
        for r in run_task(t, rounds or QUICK["rounds"], verbose=verbose):
            rows.append((f"fig12_{r['task']}_{r['schedule']}",
                         r["bench_s"] * 1e6,
                         f"loss={r['min_train_loss']:.4f};"
                         f"acc={r['max_val_acc']:.3f};"
                         f"relsteps={r['relative_sgd_steps']:.3f};"
                         f"simW={r['sim_wall_clock_s']:.0f}s;"
                         f"upMbit={r['uplink_mbit']:.1f};"
                         f"downMbit={r['downlink_mbit']:.1f}"))
    e = run_engine_speedup(rounds=rounds or 200, verbose=verbose)
    rows.append(("engine_bucketed_vs_seed", e["engine_s"] * 1e6,
                 f"speedup={e['speedup']:.2f}x;"
                 f"rps={e['engine_rps']:.1f};"
                 f"compiles={e['compile_count']};"
                 f"grid={e['k_grid_size']}"))
    for b in run_backend_compare(rounds=rounds or 60, verbose=verbose):
        rows.append((f"engine_backend_{b['backend']}", b["bench_s"] * 1e6,
                     f"rps={b['rps']:.1f};"
                     f"dispatches={b['dispatches']};"
                     f"compiles={b['compiles']}"))
    for t in run_transport_compare(rounds=rounds or 30, verbose=verbose):
        rows.append((f"transport_{t['transport']}_{t['task']}",
                     t["bench_s"] * 1e6,
                     f"uplink_x={t['uplink_x']:.2f};"
                     f"loss={t['final_loss']:.4f};"
                     f"dloss={t['dloss']:+.4f};"
                     f"simW={t['sim_wall_clock_s']:.0f}s;"
                     f"upMbit={t['uplink_mbit']:.1f}"))
    for t in run_downlink_compare(rounds=rounds or 30, verbose=verbose):
        rows.append((f"downlink_{t['downlink']}_{t['task']}",
                     t["bench_s"] * 1e6,
                     f"downlink_x={t['downlink_x']:.2f};"
                     f"loss={t['final_loss']:.4f};"
                     f"dloss={t['dloss']:+.4f};"
                     f"stateMB={t['state_mb']:.2f};"
                     f"simW={t['sim_wall_clock_s']:.0f}s;"
                     f"upMbit={t['uplink_mbit']:.1f};"
                     f"downMbit={t['downlink_mbit']:.1f}"))
    for s in run_sampler_compare(rounds=rounds or 30, verbose=verbose):
        rows.append((f"sampler_{s['sampler']}_{s['task']}",
                     s["bench_s"] * 1e6,
                     f"rps={s['rps']:.1f};"
                     f"loss={s['final_loss']:.4f};"
                     f"efSlots={s['ef_slots']}"))
    for c in run_cohort_stream(rounds=min(rounds or 6, 6), verbose=verbose):
        rows.append((f"cohort_stream_{c['case']}_{c['task']}",
                     c["bench_s"] * 1e6,
                     f"rps={c['rps']:.1f};"
                     f"peakMB={c['peak_mb']:.2f};"
                     f"peak_x={c['peak_x']:.2f};"
                     f"loss={c['final_loss']:.4f}"))
    p = run_prefetch_overlap(rounds=rounds or 48, verbose=verbose)
    rows.append(("engine_prefetch_overlap", p["prefetch_s"] * 1e6,
                 f"speedup={p['speedup']:.2f}x;"
                 f"rps={p['rounds'] / p['prefetch_s']:.1f}"))
    return rows


def write_csv(rows: List[Tuple[str, float, str]], path: str) -> None:
    """CSV with bytes-on-wire and peak device memory as first-class columns
    (parsed back out of the ``upMbit=``/``downMbit=``/``peakMB=`` derived
    fields; empty for rows that don't measure them)."""
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "us_per_call", "uplink_mbit", "downlink_mbit",
                    "peak_mb", "derived"])
        for name, us, derived in rows:
            up = down = peak = ""
            for part in derived.split(";"):
                if part.startswith("upMbit="):
                    up = part.split("=", 1)[1]
                elif part.startswith("downMbit="):
                    down = part.split("=", 1)[1]
                elif part.startswith("peakMB="):
                    peak = part.split("=", 1)[1]
            w.writerow([name, f"{us:.1f}", up, down, peak, derived])


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds per run (small values = CI smoke)")
    ap.add_argument("--tasks", nargs="*", default=["sent140"])
    ap.add_argument("--csv", default=None,
                    help="also write the rows (incl. bytes-on-wire column) "
                         "to this CSV file")
    ap.add_argument("--quiet", action="store_true")
    a = ap.parse_args()
    all_rows = run(tasks=tuple(a.tasks), rounds=a.rounds,
                   verbose=not a.quiet)
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if a.csv:
        write_csv(all_rows, a.csv)
