"""Fig. 1 / Fig. 2 / Table 4 analogue: FedAvg schedule comparison.

Runs every schedule of Table 3 on synthetic non-IID versions of the paper's
tasks under the paper's runtime model (Eq. 5, Table 1/2 constants), and
reports: min training loss within the time budget (Fig. 1), best validation
accuracy (Fig. 2), and SGD steps relative to K-eta-fixed (Table 4).

Also benchmarks the K-bucketed round engine against the seed per-round loop
(``engine_*`` rows): real rounds/sec speedup and compile count vs. the
K-quantization grid bound (DESIGN.md §6.4).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import (FedAvgTrainer, RuntimeModel, make_eval_fn,
                        quantize_k, run_reference_rounds)
from repro.data import make_paper_task
from repro.models import small

SCHEDULES = [
    ("dsgd", "dsgd", "fixed"),
    ("K-eta-fixed", "fixed", "fixed"),
    ("K_r-rounds", "rounds", "fixed"),
    ("K_r-error", "error", "fixed"),
    ("K_r-step", "step", "fixed"),
    ("eta_r-rounds", "fixed", "rounds"),
    ("eta_r-error", "fixed", "error"),
    ("eta_r-step", "fixed", "step"),
]

# CPU-scale round counts (the harness takes --rounds for full runs)
QUICK = dict(rounds=40, clients=30, per_round=8, k0=10, samples=30)


def run_task(task_name: str, rounds: int, *, seed: int = 0,
             verbose: bool = False) -> List[Dict]:
    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    results = []
    for name, ksch, esch in SCHEDULES:
        fed = FedConfig(total_clients=data.num_clients,
                        clients_per_round=QUICK["per_round"], rounds=rounds,
                        k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=min(task.fed.batch_size, 16),
                        loss_window=max(rounds // 8, 3),
                        plateau_patience=3,
                        k_schedule=ksch, eta_schedule=esch, seed=seed)
        params = small.init_task_model(jax.random.PRNGKey(seed), task)
        rt = RuntimeModel(task.model_size_mb, task.runtime,
                          fed.clients_per_round)
        t0 = time.time()
        tr = FedAvgTrainer(loss_fn, params, data, fed, rt,
                           eval_fn=make_eval_fn(loss_fn, data))
        h = tr.run(rounds, eval_every=max(rounds // 8, 1))
        rel = h.sgd_steps[-1] / (QUICK["k0"] * rounds * fed.clients_per_round)
        results.append({
            "task": task_name, "schedule": name,
            "min_train_loss": h.min_train_loss[-1],
            "max_val_acc": h.max_val_acc[-1] if h.max_val_acc else 0.0,
            "sim_wall_clock_s": h.wall_clock_s[-1],
            "relative_sgd_steps": rel,
            "bench_s": time.time() - t0,
        })
        if verbose:
            r = results[-1]
            print(f"  {task_name:12s} {name:12s} loss={r['min_train_loss']:.4f} "
                  f"acc={r['max_val_acc']:.3f} W={r['sim_wall_clock_s']:.0f}s "
                  f"rel_steps={rel:.2f}")
    return results


def run_engine_speedup(rounds: int = 200, *, task_name: str = "sent140",
                       clients_per_round: int = 4, batch_size: int = 4,
                       prefetch: bool = False, seed: int = 0,
                       verbose: bool = False) -> Dict:
    """K-bucketed engine vs. seed loop on the ``rounds`` K-decay schedule.

    The default config is the dispatch-bound regime the bucketing targets:
    small per-round payloads over a long horizon — where per-round python,
    dispatch and the seed loop's blocking per-round loss sync dominate.
    (The background prefetch thread targets the opposite, compute-bound
    regime — see ``run_prefetch_overlap`` — so it is off here.)

    Both loops run twice and the second (warm-executable) pass is timed, so
    the numbers are steady-state rounds/sec — the regime long federated runs
    live in — not XLA compile time.  Also reports the engine's compile count
    against its bound, the K-quantization grid size (DESIGN.md §6.4)."""
    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    fed = FedConfig(total_clients=data.num_clients,
                    clients_per_round=clients_per_round, rounds=rounds,
                    k0=QUICK["k0"], eta0=task.fed.eta0,
                    batch_size=batch_size, k_schedule="rounds",
                    k_quantize=True, prefetch=prefetch, seed=seed)
    grid = len({quantize_k(k, fed.k0) for k in range(1, fed.k0 + 1)})
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)

    ref = run_reference_rounds(loss_fn, params0, data, fed, rounds)  # warm-up
    seed_compiles = len(set(ref.ks))
    t0 = time.time()
    run_reference_rounds(loss_fn, params0, data, fed, rounds,
                         round_fn=ref.round_fn)
    seed_s = time.time() - t0

    rt = RuntimeModel(task.model_size_mb, task.runtime, fed.clients_per_round)
    tr = FedAvgTrainer(loss_fn, params0, data, fed, rt)
    tr.run(rounds)                                                  # warm-up
    t0 = time.time()
    tr.run(rounds)     # loss-free schedule: identical K trajectory, warm jit
    engine_s = time.time() - t0

    out = {"rounds": rounds, "seed_s": seed_s, "engine_s": engine_s,
           "speedup": seed_s / engine_s,
           "seed_rps": rounds / seed_s, "engine_rps": rounds / engine_s,
           "compile_count": tr.compile_count, "seed_compiles": seed_compiles,
           "k_grid_size": grid}
    if verbose:
        print(f"  engine_bucketed[{task_name}]: {out['engine_rps']:.1f} "
              f"rounds/s vs seed {out['seed_rps']:.1f} rounds/s "
              f"({out['speedup']:.2f}x); compiles {out['compile_count']} <= "
              f"grid {grid} (seed loop: {seed_compiles})")
    return out


def run_backend_compare(rounds: int = 60, *, task_name: str = "sent140",
                        clients_per_round: int = 4, batch_size: int = 4,
                        seed: int = 0, verbose: bool = False) -> List[Dict]:
    """Local vs mesh ExecutionBackend on the same K-decay run (DESIGN.md §7).

    Both backends drive the identical FedAvgTrainer/K-bucketed scan; the
    mesh rows run on the host-device (devices x 1) data x model mesh —
    degenerate on 1 CPU device, but the same GSPMD/jit path a pod takes.
    Reports warm rounds/sec plus dispatch and compile counts, so the
    K-bucket amortisation (dispatches << rounds) is visible on both paths.
    """
    from repro.core.engine import MeshBackend

    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, clients_per_round)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    backends = [
        ("local", lambda: None),
        ("mesh_parallel", lambda: MeshBackend(mesh, strategy="parallel")),
        ("mesh_sequential", lambda: MeshBackend(mesh, strategy="sequential",
                                                groups=2)),
    ]
    out = []
    for name, mk in backends:
        fed = FedConfig(total_clients=data.num_clients,
                        clients_per_round=clients_per_round, rounds=rounds,
                        k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=batch_size, k_schedule="rounds",
                        k_quantize=True, seed=seed)
        tr = FedAvgTrainer(loss_fn, params0, data, fed, rt, backend=mk())
        tr.run(rounds)                                          # warm-up
        d0 = tr.engine.dispatch_count
        t0 = time.time()
        tr.run(rounds)
        dt = time.time() - t0
        row = {"backend": name, "rounds": rounds, "bench_s": dt,
               "rps": rounds / dt, "dispatches": tr.engine.dispatch_count - d0,
               "compiles": tr.compile_count}
        out.append(row)
        if verbose:
            print(f"  engine_backend[{name}]: {row['rps']:.1f} rounds/s, "
                  f"{row['dispatches']} dispatches / {rounds} rounds, "
                  f"{row['compiles']} compiles")
    return out


def run_prefetch_overlap(rounds: int = 48, *, seed: int = 0,
                         verbose: bool = False) -> Dict:
    """Background prefetch thread vs. the inline builder on a compute-bound
    config (large batches, fixed K0, periodic eval).

    Expected ≈1.0x on CPU: async dispatch already hides the depth-1 inline
    build behind the previous bucket's device work, so this row is an
    overhead check — the thread must not cost throughput.  Its value is the
    double-buffering contract for regimes where the main thread blocks
    (frequent feedback syncs, blocking dispatch) — see DESIGN.md §6.5/§6.6."""
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params0 = small.init_task_model(jax.random.PRNGKey(seed), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 8)
    eval_fn = make_eval_fn(loss_fn, data)
    trainers = {}
    for prefetch in (False, True):
        fed = FedConfig(total_clients=data.num_clients, clients_per_round=8,
                        rounds=rounds, k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=32, k_schedule="fixed",
                        prefetch=prefetch, seed=seed)
        tr = FedAvgTrainer(loss_fn, params0, data, fed, rt, eval_fn=eval_fn)
        tr.run(rounds, eval_every=8)                                # warm-up
        trainers[prefetch] = tr
    times = {False: [], True: []}
    for _ in range(3):                     # alternate legs; min vs host noise
        for prefetch in (False, True):
            t0 = time.time()
            trainers[prefetch].run(rounds, eval_every=8)
            times[prefetch].append(time.time() - t0)
    out = {"rounds": rounds, "sync_s": min(times[False]),
           "prefetch_s": min(times[True]),
           "speedup": min(times[False]) / min(times[True])}
    if verbose:
        print(f"  prefetch_overlap: {rounds / out['prefetch_s']:.1f} rounds/s "
              f"vs sync {rounds / out['sync_s']:.1f} rounds/s "
              f"({out['speedup']:.2f}x)")
    return out


def run(tasks=("sent140", "femnist"), rounds=None,
        verbose=True) -> List[Tuple[str, float, str]]:
    rows = []
    for t in tasks:
        for r in run_task(t, rounds or QUICK["rounds"], verbose=verbose):
            rows.append((f"fig12_{r['task']}_{r['schedule']}",
                         r["bench_s"] * 1e6,
                         f"loss={r['min_train_loss']:.4f};"
                         f"acc={r['max_val_acc']:.3f};"
                         f"relsteps={r['relative_sgd_steps']:.3f};"
                         f"simW={r['sim_wall_clock_s']:.0f}s"))
    e = run_engine_speedup(rounds=rounds or 200, verbose=verbose)
    rows.append(("engine_bucketed_vs_seed", e["engine_s"] * 1e6,
                 f"speedup={e['speedup']:.2f}x;"
                 f"rps={e['engine_rps']:.1f};"
                 f"compiles={e['compile_count']};"
                 f"grid={e['k_grid_size']}"))
    for b in run_backend_compare(rounds=rounds or 60, verbose=verbose):
        rows.append((f"engine_backend_{b['backend']}", b["bench_s"] * 1e6,
                     f"rps={b['rps']:.1f};"
                     f"dispatches={b['dispatches']};"
                     f"compiles={b['compiles']}"))
    p = run_prefetch_overlap(rounds=rounds or 48, verbose=verbose)
    rows.append(("engine_prefetch_overlap", p["prefetch_s"] * 1e6,
                 f"speedup={p['speedup']:.2f}x;"
                 f"rps={p['rounds'] / p['prefetch_s']:.1f}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=None,
                    help="rounds per run (small values = CI smoke)")
    ap.add_argument("--tasks", nargs="*", default=["sent140"])
    ap.add_argument("--quiet", action="store_true")
    a = ap.parse_args()
    for name, us, derived in run(tasks=tuple(a.tasks), rounds=a.rounds,
                                 verbose=not a.quiet):
        print(f"{name},{us:.1f},{derived}")
