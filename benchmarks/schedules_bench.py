"""Fig. 1 / Fig. 2 / Table 4 analogue: FedAvg schedule comparison.

Runs every schedule of Table 3 on synthetic non-IID versions of the paper's
tasks under the paper's runtime model (Eq. 5, Table 1/2 constants), and
reports: min training loss within the time budget (Fig. 1), best validation
accuracy (Fig. 2), and SGD steps relative to K-eta-fixed (Table 4).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import FedAvgTrainer, RuntimeModel, make_eval_fn
from repro.data import make_paper_task
from repro.models import small

SCHEDULES = [
    ("dsgd", "dsgd", "fixed"),
    ("K-eta-fixed", "fixed", "fixed"),
    ("K_r-rounds", "rounds", "fixed"),
    ("K_r-error", "error", "fixed"),
    ("K_r-step", "step", "fixed"),
    ("eta_r-rounds", "fixed", "rounds"),
    ("eta_r-error", "fixed", "error"),
    ("eta_r-step", "fixed", "step"),
]

# CPU-scale round counts (the harness takes --rounds for full runs)
QUICK = dict(rounds=40, clients=30, per_round=8, k0=10, samples=30)


def run_task(task_name: str, rounds: int, *, seed: int = 0,
             verbose: bool = False) -> List[Dict]:
    task = get_paper_task(task_name)
    data = make_paper_task(task_name, np.random.default_rng(seed),
                           num_clients=QUICK["clients"],
                           samples_per_client=QUICK["samples"])
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    results = []
    for name, ksch, esch in SCHEDULES:
        fed = FedConfig(total_clients=data.num_clients,
                        clients_per_round=QUICK["per_round"], rounds=rounds,
                        k0=QUICK["k0"], eta0=task.fed.eta0,
                        batch_size=min(task.fed.batch_size, 16),
                        loss_window=max(rounds // 8, 3),
                        plateau_patience=3,
                        k_schedule=ksch, eta_schedule=esch, seed=seed)
        params = small.init_task_model(jax.random.PRNGKey(seed), task)
        rt = RuntimeModel(task.model_size_mb, task.runtime,
                          fed.clients_per_round)
        t0 = time.time()
        tr = FedAvgTrainer(loss_fn, params, data, fed, rt,
                           eval_fn=make_eval_fn(loss_fn, data))
        h = tr.run(rounds, eval_every=max(rounds // 8, 1))
        rel = h.sgd_steps[-1] / (QUICK["k0"] * rounds * fed.clients_per_round)
        results.append({
            "task": task_name, "schedule": name,
            "min_train_loss": h.min_train_loss[-1],
            "max_val_acc": h.max_val_acc[-1] if h.max_val_acc else 0.0,
            "sim_wall_clock_s": h.wall_clock_s[-1],
            "relative_sgd_steps": rel,
            "bench_s": time.time() - t0,
        })
        if verbose:
            r = results[-1]
            print(f"  {task_name:12s} {name:12s} loss={r['min_train_loss']:.4f} "
                  f"acc={r['max_val_acc']:.3f} W={r['sim_wall_clock_s']:.0f}s "
                  f"rel_steps={rel:.2f}")
    return results


def run(tasks=("sent140", "femnist"), rounds=None,
        verbose=True) -> List[Tuple[str, float, str]]:
    rows = []
    for t in tasks:
        for r in run_task(t, rounds or QUICK["rounds"], verbose=verbose):
            rows.append((f"fig12_{r['task']}_{r['schedule']}",
                         r["bench_s"] * 1e6,
                         f"loss={r['min_train_loss']:.4f};"
                         f"acc={r['max_val_acc']:.3f};"
                         f"relsteps={r['relative_sgd_steps']:.3f};"
                         f"simW={r['sim_wall_clock_s']:.0f}s"))
    return rows
