"""Async-vs-sync event-clock record for the CI perf gate (DESIGN.md §13).

The async buffered engine's whole point is straggler immunity: under client
heterogeneity the synchronous round waits for the cohort's slowest client
(Eq. 4's max over lognormal multipliers) while the buffer applies as soon as
``buffer_size`` fast arrivals land. One gated record in the kernel-record
schema (``kernel_us``/``oracle_us``/``max_abs_delta``) so
``benchmarks.perf_gate`` applies its machine-robust ratio/delta checks:

  * ``async_speedup_wall`` — ``oracle_us`` is the synchronous run's total
    simulated wall-clock; ``kernel_us`` is the async event-clock wall at the
    first apply whose best training loss matches the sync run's final best
    loss (within the 2% band); ``max_abs_delta`` is the relative loss gap at
    that point (0 when async meets the target inside the band). Both walls
    come off the SAME seeded RuntimeModel heterogeneity draw
    (``draw_client_times``), so the ratio is deterministic — the gate's
    ratio check then enforces that async stays a real speedup (the
    committed baseline ratio is ~0.3x; the 4x gate factor still requires
    well under 1.3x sync wall).

Extra keys (``mean_staleness``/``p95_staleness``/``sync_wall_s``/
``async_wall_s``) ride along for humans; the gate ignores unknown keys.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

ROUNDS = 8            # synchronous reference schedule length
ASYNC_ROUNDS = 24     # async version budget to find the matched-loss apply
COHORT = 16
BUFFER = COHORT // 2
HET = 0.8             # lognormal sigma — the straggler spread (>= 0.5)
LOSS_BAND = 0.02      # matched "final loss" tolerance (2%)


def _spec(*extra):
    from repro.api import ExperimentSpec
    return ExperimentSpec().with_overrides(
        "data.kind=paper", "data.task=femnist", "data.clients=32",
        "data.samples_per_client=16", "data.seed=0",
        f"fed.clients_per_round={COHORT}", f"fed.rounds={ROUNDS}",
        "fed.k0=4", "fed.eta0=0.3", "fed.batch_size=8",
        "fed.k_schedule=rounds", "fed.eval_every=0", "fed.seed=0",
        f"runtime.heterogeneity={HET}", *extra)


def run_records() -> List[dict]:
    from repro.api import build
    hs = build(_spec("fed.aggregation=sync")).run()
    sync_min = hs.min_train_loss[-1]
    sync_wall = hs.wall_clock_s[-1]

    exp = build(_spec("fed.aggregation=async",
                      f"fed.buffer_size={BUFFER}",
                      "fed.staleness_weight=inv"))
    ha = exp.trainer.run(ASYNC_ROUNDS)
    target = sync_min * (1.0 + LOSS_BAND)
    hit = next((i for i, l in enumerate(ha.min_train_loss) if l <= target),
               None)
    if hit is None:                    # never matched: report the full run's
        hit = len(ha.rounds) - 1       # gap honestly — the gate trips on it
    async_wall = ha.wall_clock_s[hit]
    gap = max(0.0, (ha.min_train_loss[hit] - sync_min) / sync_min)
    stale = ha.staleness[:hit + 1]
    return [
        {"name": "async_speedup_wall",
         # event-clock seconds reported as "us" — only the ratio is gated
         "kernel_us": async_wall * 1e6, "oracle_us": sync_wall * 1e6,
         "max_abs_delta": gap,
         "sync_wall_s": sync_wall, "async_wall_s": async_wall,
         "mean_staleness": float(np.mean(stale)),
         "p95_staleness": float(np.percentile(stale, 95))},
    ]


def rows_from_records(recs: List[dict]) -> List[Tuple[str, float, str]]:
    return [(r["name"], r["kernel_us"],
             f"oracle_us={r['oracle_us']:.1f};"
             f"speedup={r['oracle_us'] / r['kernel_us']:.2f}x;"
             f"max_abs_delta={r['max_abs_delta']:.3g};"
             f"mean_staleness={r['mean_staleness']:.2f};"
             f"p95_staleness={r['p95_staleness']:.2f}")
            for r in recs]


def run(verbose=True, records: List[dict] = None
        ) -> List[Tuple[str, float, str]]:
    rows = rows_from_records(records if records is not None
                             else run_records())
    if verbose:
        for n, us, d in rows:
            print(f"  {n:32s} {us:12.0f}us  {d}")
    return rows
