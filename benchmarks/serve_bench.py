"""Serve-while-training records for the CI perf gate (DESIGN.md §14).

A short federated LM run with the ServingLoop attached (``serve.every=1``)
over the full store bracket — int8 downlink deltas against a q8 ref store —
so the gated records exercise exactly the snapshot path production serving
uses. Two records in the kernel-record schema
(``kernel_us``/``oracle_us``/``max_abs_delta``):

  * ``serve_tokens_per_sec`` — ``kernel_us`` is the mean µs/token the live
    loop served across its in-run ticks; ``oracle_us`` is µs/token of the
    same jitted decode step driven directly with the client-view tree
    (``downlink.load_tree(ref)``) outside the loop. The ratio is ~1 and
    machine-robust (same executable, same shapes); ``max_abs_delta`` is the
    max |id difference| between the tokens the served snapshot generates
    and the tokens the client-view tree generates — 0 by the snapshot
    contract (``store.snapshot()`` returns the exact tree clients hold).
  * ``serve_swap_us`` — ``kernel_us`` is the mean hot-swap latency
    (snapshot + q8 dequantise, materialised) across ticks; ``oracle_us``
    re-times the bare ``load_tree`` reconstruction eagerly. Same work on
    both sides, so the ratio gates a swap path that starts re-encoding or
    copying extra state; ``max_abs_delta`` is the max leafwise
    |snapshot - load_tree(ref)| — bitwise 0.

Extra keys (``max_staleness``/``ticks``/``store_version``) ride along for
humans; the gate ignores unknown keys.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

ROUNDS = 4
REPS = 3              # oracle re-timing repetitions (mean)


def _spec(*extra):
    from repro.api import ExperimentSpec
    return ExperimentSpec().with_overrides(
        "model.arch=qwen1.5-0.5b", "model.reduced=true",
        "data.kind=lm", "data.clients=8", "data.samples_per_client=8",
        "data.seq_len=16", "data.seed=0",
        f"fed.rounds={ROUNDS}", "fed.clients_per_round=4",
        "fed.k0=2", "fed.eta0=0.05", "fed.batch_size=4",
        "fed.k_schedule=rounds", "fed.loss_window=3",
        "fed.bucket_rounds=2", "fed.seed=0",
        "transport.name=int8", "transport.downlink=int8",
        "transport.ref_store=q8",
        "serve.every=1", "serve.qps=25.0", "serve.query_ms=2.0",
        "runtime.beta_seconds=0.05", *extra)


def run_records() -> List[dict]:
    import jax

    from repro.api import build

    exp = build(_spec())
    h = exp.run()
    trainer = exp.trainer
    loop, store = trainer.serving, trainer.store
    dl = store.downlink

    # the client-view oracle tree: what every client reconstructs from the
    # broadcast reference — snapshot() must hand serving this exact tree
    _, snap = store.snapshot()
    snap = jax.block_until_ready(snap)
    ref = jax.block_until_ready(
        dl.load_tree(store.downlink_state["ref"], like=store.params))
    swap_delta = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                     for a, b in zip(jax.tree.leaves(snap),
                                     jax.tree.leaves(ref)))

    swap_us = [0.0] * REPS
    for i in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(
            dl.load_tree(store.downlink_state["ref"], like=store.params))
        swap_us[i] = (time.perf_counter() - t0) * 1e6

    prompts = loop._traffic(0)
    served_ids, _ = loop.decode(prompts, params=snap)
    oracle_ids = None
    dts = [0.0] * REPS
    for i in range(REPS):
        oracle_ids, dts[i] = loop.decode(prompts, params=ref)
    tok_delta = float(np.max(np.abs(np.asarray(served_ids, dtype=np.int64)
                                    - np.asarray(oracle_ids,
                                                 dtype=np.int64))))
    per_tok = loop.batch * loop.tokens
    return [
        {"name": "serve_tokens_per_sec",
         "kernel_us": float(np.mean([1e6 / t
                                     for t in h.serve_tokens_per_sec])),
         "oracle_us": float(np.mean(dts)) * 1e6 / per_tok,
         "max_abs_delta": tok_delta,
         "mean_tokens_per_sec": float(np.mean(h.serve_tokens_per_sec)),
         "ticks": len(h.serve_rounds)},
        {"name": "serve_swap_us",
         "kernel_us": float(np.mean(h.serve_swap_us)),
         "oracle_us": float(np.mean(swap_us)),
         "max_abs_delta": swap_delta,
         "max_staleness": int(max(h.serve_staleness)),
         "store_version": store.version},
    ]


def rows_from_records(recs: List[dict]) -> List[Tuple[str, float, str]]:
    rows = []
    for r in recs:
        extras = ";".join(f"{k}={v:.3g}" if isinstance(v, float)
                          else f"{k}={v}"
                          for k, v in r.items()
                          if k not in ("name", "kernel_us", "oracle_us",
                                       "max_abs_delta"))
        rows.append((r["name"], r["kernel_us"],
                     f"oracle_us={r['oracle_us']:.1f};"
                     f"max_abs_delta={r['max_abs_delta']:.3g};" + extras))
    return rows


def run(verbose=True, records: List[dict] = None
        ) -> List[Tuple[str, float, str]]:
    rows = rows_from_records(records if records is not None
                             else run_records())
    if verbose:
        for n, us, d in rows:
            print(f"  {n:32s} {us:12.1f}us  {d}")
    return rows
