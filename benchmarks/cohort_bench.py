"""Cohort-scaling records for the CI perf gate (DESIGN.md §11).

Chunked streaming cohorts must stay equivalent to the dense round they
replace — in params AND in cost. Three gated records, in the same schema as
the kernel records (``kernel_us``/``oracle_us``/``max_abs_delta``) so
``benchmarks.perf_gate`` applies the identical machine-robust checks:

  * ``cohort_scaling_round_c2`` — chunked (C=2) vs dense per-round wall
    time; ``max_abs_delta`` is the params divergence after the run (the
    streaming tolerance: only f32 partial-sum reorder).
  * ``cohort_scaling_bitwise_cU`` — chunk == U vs dense: the single slab
    preserves the dense summation order, so the delta must be exactly 0.
  * ``cohort_scaling_peak_mb`` — chunked vs dense executable peak device
    MB (``repro.core.mem``); the "timing" ratio check then gates the
    memory ratio, catching a chunked path that silently rematerialises the
    full cohort.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax.numpy as jnp
import jax

ROUNDS = 3
COHORT = 16
CHUNK = 2


def _spec(chunk=None):
    from repro.api import ExperimentSpec
    spec = ExperimentSpec().with_overrides(
        "data.kind=paper", "data.task=femnist", "data.clients=32",
        "data.samples_per_client=16", "data.seed=0",
        f"fed.clients_per_round={COHORT}", f"fed.rounds={ROUNDS}",
        "fed.k0=4", "fed.eta0=0.3", "fed.batch_size=8",
        "fed.k_schedule=fixed", "fed.bucket_rounds=1", "fed.eval_every=0",
        "fed.seed=0")
    if chunk:
        spec = spec.with_overrides(f"fed.cohort_chunk={chunk}")
    return spec


def _run(spec):
    from repro.api import build
    from repro.core import trainer_peak_mb
    exp = build(spec)
    exp.run()                                                   # warm-up
    t0 = time.time()
    exp.run()
    return exp, time.time() - t0, trainer_peak_mb(exp.trainer)


def _delta(a, b) -> float:
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def run_records() -> List[dict]:
    dense, dense_s, dense_peak = _run(_spec())
    c2, c2_s, c2_peak = _run(_spec(CHUNK))
    cu, cu_s, _ = _run(_spec(COHORT))
    per_round = 1e6 / ROUNDS
    return [
        {"name": "cohort_scaling_round_c2",
         "kernel_us": c2_s * per_round, "oracle_us": dense_s * per_round,
         "max_abs_delta": _delta(c2.params, dense.params)},
        {"name": "cohort_scaling_bitwise_cU",
         "kernel_us": cu_s * per_round, "oracle_us": dense_s * per_round,
         "max_abs_delta": _delta(cu.params, dense.params)},
        {"name": "cohort_scaling_peak_mb",
         "kernel_us": c2_peak, "oracle_us": dense_peak,
         "max_abs_delta": 0.0},
    ]


def rows_from_records(recs: List[dict]) -> List[Tuple[str, float, str]]:
    return [(r["name"], r["kernel_us"],
             f"oracle_us={r['oracle_us']:.1f};"
             f"ratio={r['kernel_us'] / r['oracle_us']:.3f};"
             f"max_abs_delta={r['max_abs_delta']:.3g}")
            for r in recs]


def run(verbose=True, records: List[dict] = None
        ) -> List[Tuple[str, float, str]]:
    rows = rows_from_records(records if records is not None
                             else run_records())
    if verbose:
        for n, us, d in rows:
            print(f"  {n:32s} {us:12.0f}us  {d}")
    return rows
