"""Property-based tests (hypothesis) on the system's invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import DecayController, RuntimeModel, quantize_k, theory
from repro.core.schedules import schedule_preview
from repro.kernels import fedavg_reduce as fr
from repro.models.transformer import xent_loss

SET = dict(max_examples=25, deadline=None)


@given(k0=st.integers(1, 200), rounds=st.integers(1, 300),
       sched=st.sampled_from(["rounds", "cosine", "fixed", "dsgd"]))
@settings(**SET)
def test_k_schedule_invariants(k0, rounds, sched):
    ks = schedule_preview(FedConfig(k0=k0, rounds=rounds, k_schedule=sched),
                          rounds)
    assert len(ks) == rounds
    assert all(1 <= k <= k0 for k in ks)
    assert all(a >= b for a, b in zip(ks, ks[1:]))      # monotone decay


@given(k=st.integers(1, 500), k0=st.integers(1, 500))
@settings(**SET)
def test_quantize_k_bounds(k, k0):
    kq = quantize_k(min(k, k0), k0)
    assert 1 <= kq <= k0


@given(ks=st.lists(st.integers(1, 100), min_size=1, max_size=50),
       size=st.floats(0.1, 100), beta=st.floats(1e-4, 2.0))
@settings(**SET)
def test_runtime_model_total_equals_sum_of_rounds(ks, size, beta):
    rt = RuntimeModel(size, RuntimeModelConfig(beta_seconds=beta), 10)
    total = rt.total_time(ks)
    per_round = sum(rt.round_cost(k).wall_clock_s for k in ks)
    assert math.isclose(total, per_round, rel_tol=1e-9)
    # dsgd (K=1) is always the cheapest-compute schedule
    assert rt.total_sgd_steps([1] * len(ks)) <= rt.total_sgd_steps(ks)


@given(n=st.integers(2, 12), m=st.integers(1, 300), seed=st.integers(0, 99))
@settings(**SET)
def test_fedavg_reduce_is_convex_combination(n, m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    w = jnp.asarray(rng.dirichlet(np.ones(n)).astype(np.float32))
    out = np.asarray(fr.fedavg_reduce(x, w, interpret=True))
    lo = np.asarray(x).min(axis=0) - 1e-5
    hi = np.asarray(x).max(axis=0) + 1e-5
    assert (out >= lo).all() and (out <= hi).all()
    # permutation invariance
    perm = rng.permutation(n)
    out_p = np.asarray(fr.fedavg_reduce(x[perm], w[perm], interpret=True))
    np.testing.assert_allclose(out, out_p, rtol=1e-5, atol=1e-6)


@given(b=st.integers(1, 4), s=st.integers(2, 16), v=st.integers(2, 50),
       seed=st.integers(0, 99))
@settings(**SET)
def test_xent_loss_matches_manual(b, s, v, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, s, v)).astype(np.float32))
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)).astype(np.int32))
    got = float(xent_loss(logits, targets))
    lp = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    want = -np.mean([lp[i, j, targets[i, j]] for i in range(b)
                     for j in range(s)])
    assert math.isclose(got, float(want), rel_tol=1e-4)
    assert got >= 0.0


@given(eta=st.floats(1e-4, 0.0625), n=st.integers(1, 64),
       f0=st.floats(0.1, 100.0))
@settings(**SET)
def test_theorem2_monotonicity(eta, n, f0):
    pc = theory.ProblemConstants(L=4.0, mu=1.0, sigma_sq=0.1, gamma=0.1,
                                 g_sq=1.0, f0=f0, f_star=0.0, n_clients=n)
    k1 = theory.optimal_k(pc, eta, f0, comm_time_s=1.0, horizon_s=10.0)
    k2 = theory.optimal_k(pc, eta, f0 / 2, comm_time_s=1.0, horizon_s=10.0)
    assert k2 <= k1 + 1e-9          # lower loss => smaller optimal K (Eq. 9)
    k3 = theory.optimal_k(pc, eta, f0, comm_time_s=2.0, horizon_s=10.0)
    assert k3 >= k1 - 1e-9          # pricier comms => larger optimal K
