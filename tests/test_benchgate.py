"""CI perf-gate + roofline bench plumbing (DESIGN.md §10.5).

The gate's job is to fail loudly: on a numerics regression vs the jnp
oracles, on an order-of-magnitude kernel/oracle timing-ratio shift, and on
a gated row silently vanishing from the bench. The roofline runner's job
is never to green-light an empty table.
"""
import json

import pytest

from benchmarks import perf_gate, roofline_bench


def _rec(name, kernel_us, oracle_us, delta):
    return {"name": name, "kernel_us": kernel_us, "oracle_us": oracle_us,
            "max_abs_delta": delta}


BASELINE = [
    _rec("kern_fedavg_reduce", 100.0, 120.0, 4e-7),
    _rec("kern_topk_scatter_reduce_mosaic", 500.0, 100.0, 0.0),
    _rec("kern_flash_attention", 50.0, None, 1e-3),      # ungated row
]


# ---------------------------------------------------------------------------
# perf_gate.check
# ---------------------------------------------------------------------------

def test_gate_passes_on_identical_records():
    assert perf_gate.check(BASELINE, BASELINE) == []


def test_gate_flags_timing_ratio_regression():
    cur = [dict(r) for r in BASELINE]
    cur[1]["kernel_us"] = 500.0 * 100          # mosaic path fell off a cliff
    msgs = perf_gate.check(cur, BASELINE)
    assert len(msgs) == 1
    assert "kern_topk_scatter_reduce_mosaic" in msgs[0]
    assert "ratio" in msgs[0]


def test_gate_flags_numerics_regression():
    cur = [dict(r) for r in BASELINE]
    cur[0]["max_abs_delta"] = 0.5
    msgs = perf_gate.check(cur, BASELINE)
    assert len(msgs) == 1
    assert "kern_fedavg_reduce" in msgs[0] and "max_abs_delta" in msgs[0]


def test_gate_missing_gated_row_fails():
    cur = [r for r in BASELINE if r["name"] != "kern_fedavg_reduce"]
    msgs = perf_gate.check(cur, BASELINE)
    assert msgs and "missing" in msgs[0]


def test_gate_ignores_ungated_rows():
    """Attention/SSD/MoE rows carry no oracle contract here — an extra or
    regressed ungated row must not trip the wire-path gate."""
    cur = [dict(r) for r in BASELINE]
    cur[2]["kernel_us"] = 1e9
    cur[2]["max_abs_delta"] = 1e9
    assert perf_gate.check(cur, BASELINE) == []
    assert perf_gate.check(BASELINE, BASELINE + [
        _rec("kern_ssd_scan", 1.0, None, 0.0)]) == []


def test_gate_timing_floor_absorbs_fast_oracle_noise():
    """A kernel far *faster* than its oracle gates on the ratio floor, not
    on a noise-scale baseline ratio."""
    base = [_rec("kern_topk_scatter_reduce_xla", 1.0, 10000.0, 0.0)]
    cur = [_rec("kern_topk_scatter_reduce_xla", 3.0, 10000.0, 0.0)]
    assert perf_gate.check(cur, base) == []    # 3x jitter under the floor


def test_gate_load_records_wrapped_and_bare(tmp_path):
    wrapped = tmp_path / "wrapped.json"
    wrapped.write_text(json.dumps({"jax": "0.0", "records": BASELINE}))
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(BASELINE))
    assert perf_gate.load_records(str(wrapped)) == BASELINE
    assert perf_gate.load_records(str(bare)) == BASELINE


def test_gate_cli_exit_codes(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"records": BASELINE}))
    bad = tmp_path / "bad.json"
    regressed = [dict(r) for r in BASELINE]
    regressed[0]["max_abs_delta"] = 0.5
    bad.write_text(json.dumps({"records": regressed}))
    perf_gate.main(["--current", str(good), "--baseline", str(good)])
    with pytest.raises(SystemExit) as e:
        perf_gate.main(["--current", str(bad), "--baseline", str(good)])
    assert e.value.code == 1
    assert "perf gate FAILED" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# roofline_bench: empty record sets must be loud, never silently green
# ---------------------------------------------------------------------------

def test_roofline_load_records_empty_and_populated(tmp_path):
    assert roofline_bench.load_records(str(tmp_path)) == []
    rec = {"status": "skipped", "case": "a1", "reason": "no-tpu:host"}
    (tmp_path / "a1.json").write_text(json.dumps(rec))
    assert roofline_bench.load_records(str(tmp_path)) == [rec]


def test_roofline_strict_raises_on_empty(tmp_path):
    with pytest.raises(SystemExit, match="no dry-run records"):
        roofline_bench.run(verbose=False, strict=True,
                           dirname=str(tmp_path))


def test_roofline_nonstrict_emits_explicit_skip_row(tmp_path):
    rows = roofline_bench.run(verbose=False, dirname=str(tmp_path))
    assert rows == [("roofline_all", 0.0, "SKIPPED:no-dryrun-records")]


def test_roofline_rows_from_records(tmp_path):
    rec = {"status": "skipped", "case": "a1", "reason": "no-tpu:host"}
    (tmp_path / "a1.json").write_text(json.dumps(rec))
    rows = roofline_bench.run(verbose=False, dirname=str(tmp_path))
    assert rows == [("roofline_a1", 0.0, "skipped:no-tpu")]
