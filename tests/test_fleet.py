"""Fleet driver + cross-experiment executable sharing (DESIGN.md §12).

Covers the PR-8 contracts:
  * sweep syntax: comma lists / grid expansion / loud unknown-path errors;
  * ``fed.k_grid0``: pinned quantize anchor collapses a k0 sweep onto one
    bucket signature (and validates loudly);
  * registry counters: a registry hit from another experiment is a
    ``shared_count``, never a local compile — and the adopted executable
    is the SAME object (bitwise-shared program);
  * key isolation: transport codecs / backends / mesh slices never
    collide;
  * the driver: packed == serial results, consolidated CSV/leaderboard.
"""
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, expand_sweep, sweep_grid
from repro.api.spec import SpecValidationError
from repro.api.sweep import spec_program_key
from repro.core.engine.round import ExecutableRegistry


def _base(**kw):
    ov = ["data.kind=paper", "data.task=femnist", "data.clients=8",
          "data.samples_per_client=8", "fed.clients_per_round=4",
          "fed.rounds=2", "fed.batch_size=4", "fed.bucket_rounds=2",
          "fed.eta0=0.3"]
    ov += [f"{k}={v}" for k, v in kw.items()]
    return ExperimentSpec().with_overrides(*ov)


# ---------------------------------------------------------------------------
# sweep syntax
# ---------------------------------------------------------------------------

class TestSweepSyntax:
    def test_grid_cross_product(self):
        pts = expand_sweep("fed.k0=2,4,8", "transport.name=none,int8",
                           base=_base())
        assert len(pts) == 6
        labels = {p.label for p in pts}
        assert "k0=2|name=none" in labels and "k0=8|name=int8" in labels
        k0s = sorted({p.spec.fed.k0 for p in pts})
        assert k0s == [2, 4, 8]

    def test_single_value_axis(self):
        pts = expand_sweep("fed.k0=4", base=_base())
        assert len(pts) == 1 and pts[0].spec.fed.k0 == 4

    def test_unknown_paths_aggregate_loudly(self):
        with pytest.raises(SpecValidationError) as ei:
            expand_sweep("fed.nope=1,2", "bogus.k0=1", base=_base())
        msg = str(ei.value)
        assert "fed.nope" in msg and "bogus" in msg

    def test_bad_value_reports_point_label(self):
        with pytest.raises(SpecValidationError) as ei:
            expand_sweep("transport.name=int8,not_a_codec", base=_base())
        assert "not_a_codec" in str(ei.value)

    def test_grid_labels_unique_per_point(self):
        grid = sweep_grid(["fed.k0=2,4", "fed.eta0=0.1,0.2"])
        labels = [label for _, label in grid]
        assert len(labels) == len(set(labels)) == 4

    def test_comma_list_coerces_on_tuple_field(self):
        spec = ExperimentSpec().with_overrides("sampler.cohort=0,1,2")
        assert spec.sampler.cohort == (0, 1, 2)

    def test_comma_list_on_scalar_field_hints_sweep(self):
        with pytest.raises(SpecValidationError) as ei:
            ExperimentSpec().with_overrides("fed.k0=2,4,8")
        assert "sweep" in str(ei.value)


# ---------------------------------------------------------------------------
# k_grid0
# ---------------------------------------------------------------------------

class TestKGrid0:
    def test_anchor_snaps_k0_range_to_one_k(self):
        from repro.configs.base import FedConfig
        from repro.core.schedules import DecayController
        ks = set()
        for k0 in (12, 14, 15, 16):
            fed = FedConfig(k0=k0, k_quantize=True, k_grid0=16,
                            k_schedule="fixed")
            ks.add(DecayController(fed).k_for_round(1))
        assert ks == {16}

    def test_none_anchor_keeps_k0_grid(self):
        from repro.configs.base import FedConfig
        from repro.core.schedules import DecayController
        fed = FedConfig(k0=12, k_quantize=True, k_schedule="fixed")
        assert DecayController(fed).k_for_round(1) == 12

    def test_validation_requires_quantize(self):
        with pytest.raises(SpecValidationError) as ei:
            _base(**{"fed.k_grid0": 16}).validate()
        assert "k_quantize" in str(ei.value)

    def test_validation_rejects_nonpositive(self):
        with pytest.raises(SpecValidationError):
            _base(**{"fed.k_quantize": "true",
                     "fed.k_grid0": 0}).validate()

    def test_spec_roundtrip(self):
        spec = _base(**{"fed.k_quantize": "true", "fed.k_grid0": 16})
        assert ExperimentSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# registry sharing + counters
# ---------------------------------------------------------------------------

class TestRegistrySharing:
    def test_shared_hit_not_double_counted(self):
        reg = ExecutableRegistry()
        spec = _base().validate()
        a = build(spec, registry=reg)
        b = build(spec, registry=reg)
        a.run()
        b.run()
        assert a.trainer.compile_count == 1
        assert a.trainer.shared_count == 0
        # B adopted A's executable: a shared_count, NOT a local compile
        assert b.trainer.compile_count == 0
        assert b.trainer.shared_count == 1
        assert reg.compile_count == 1
        assert reg.hits == 1 and reg.misses == 1

    def test_shared_executable_is_same_object(self):
        reg = ExecutableRegistry()
        a = build(_base(), registry=reg)
        b = build(_base(), registry=reg)
        a.run()
        b.run()
        ex_a = list(a.trainer.engine._executables.values())
        ex_b = list(b.trainer.engine._executables.values())
        assert len(ex_a) == len(ex_b) == 1
        assert ex_a[0] is ex_b[0]

    def test_same_k_bucket_different_k0_shares(self):
        # the satellite contract: two points differing only in fed.k0,
        # snapped into one K grid bucket via k_grid0, share bitwise
        reg = ExecutableRegistry()
        exps = []
        for k0 in (15, 16):
            spec = _base(**{"fed.k0": k0, "fed.k_quantize": "true",
                            "fed.k_grid0": 16})
            exps.append(build(spec, registry=reg))
        for e in exps:
            e.run()
        assert exps[0].trainer.compile_count == 1
        assert exps[1].trainer.compile_count == 0
        assert exps[1].trainer.shared_count == 1
        a = list(exps[0].trainer.engine._executables.values())[0]
        b = list(exps[1].trainer.engine._executables.values())[0]
        assert a is b

    def test_transport_codecs_do_not_collide(self):
        # same shapes, different traced program -> distinct registry keys
        reg = ExecutableRegistry()
        for name in ("none", "int8"):
            e = build(_base(**{"transport.name": name}), registry=reg)
            e.run()
            assert e.trainer.shared_count == 0
        assert reg.compile_count == 2

    def test_transport_codecs_do_not_collide_mesh(self):
        reg = ExecutableRegistry()
        for name in ("none", "int8"):
            e = build(_base(**{"transport.name": name,
                               "backend.name": "mesh"}), registry=reg)
            e.run()
            assert e.trainer.shared_count == 0
        assert reg.compile_count == 2

    def test_registry_requires_program_key(self):
        from repro.core.engine.round import RoundEngine
        with pytest.raises(ValueError, match="program_key"):
            RoundEngine(lambda p, b: 0.0, registry=ExecutableRegistry())

    def test_private_registry_back_compat(self):
        e = build(_base())
        e.run()
        assert e.trainer.compile_count == 1
        assert e.trainer.shared_count == 0
        assert len(e.trainer.engine._executables) == 1

    def test_program_key_distinguishes_codec_and_backend(self):
        k_none = spec_program_key(_base())
        k_int8 = spec_program_key(_base(**{"transport.name": "int8"}))
        k_mesh = spec_program_key(_base(**{"backend.name": "mesh"}))
        assert len({k_none, k_int8, k_mesh}) == 3
        # signature-only knobs do NOT split the program key
        assert spec_program_key(_base(**{"fed.k0": 2})) == k_none

    def test_single_flight_under_concurrency(self):
        import threading
        reg = ExecutableRegistry()
        built = []

        def build_fn():
            built.append(1)
            return object()

        results = []
        threads = [threading.Thread(
            target=lambda: results.append(reg.get_or_build(("k",), build_fn)))
            for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(built) == 1
        assert len({id(r[0]) for r in results}) == 1
        assert sum(1 for r in results if r[1]) == 1


# ---------------------------------------------------------------------------
# backend slices
# ---------------------------------------------------------------------------

class CarveMesh:
    """Duck-typed mesh with a device grid, for carve_submeshes tests."""

    def __init__(self, devices, axis_names):
        self.devices = np.asarray(devices)
        self.axis_names = tuple(axis_names)

    @property
    def shape(self):
        return dict(zip(self.axis_names, self.devices.shape))


class TestFleetSlices:
    def test_carve_splits_largest_axis(self):
        from repro.core.engine.backends.mesh import carve_submeshes
        mesh = CarveMesh(np.arange(8).reshape(4, 2), ("data", "model"))
        subs = carve_submeshes(mesh, 4)
        assert len(subs) == 4
        assert all(s.devices.shape == (1, 2) for s in subs)
        assert all(s.axis_names == ("data", "model") for s in subs)
        got = sorted(d for s in subs for d in s.devices.flat)
        assert got == list(range(8))

    def test_carve_nondivisible_takes_largest_divisor(self):
        from repro.core.engine.backends.mesh import carve_submeshes
        mesh = CarveMesh(np.arange(6).reshape(6, 1), ("data", "model"))
        subs = carve_submeshes(mesh, 4)     # 4 ∤ 6 -> 3 slices of 2
        assert len(subs) == 3
        assert all(s.devices.shape == (2, 1) for s in subs)

    def test_carve_single_device_returns_self(self):
        from repro.core.engine.backends.mesh import carve_submeshes
        mesh = CarveMesh(np.arange(1).reshape(1, 1), ("data", "model"))
        assert carve_submeshes(mesh, 4) == [mesh]

    def test_local_fleet_slices_fresh_instances(self):
        from repro.core.engine.backends.local import LocalBackend
        be = LocalBackend()
        slices = be.fleet_slices(3)
        assert len(slices) == 3
        assert len({id(s) for s in slices}) == 3
        assert all(isinstance(s, LocalBackend) for s in slices)

    def test_mesh_fleet_slices_cycles_and_preserves_config(self):
        from repro.core.engine.backends.mesh import MeshBackend
        mesh = CarveMesh(np.arange(2).reshape(2, 1), ("data", "model"))
        be = MeshBackend.__new__(MeshBackend)
        be.mesh = mesh
        be.strategy = "parallel"
        be.client_axes = ("data",)
        be.groups = 1
        be.param_specs = None
        be.acc_dtype = np.float32
        be.reduce = "flat"
        slices = be.fleet_slices(4)          # 2 sub-meshes cycled over 4
        assert len(slices) == 4
        assert slices[0].mesh.devices.tolist() == slices[2].mesh.devices.tolist()
        assert all(s.strategy == "parallel" and s.reduce == "flat"
                   for s in slices)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

class TestFleetDriver:
    def _points(self):
        from repro.api.sweep import expand_sweep
        from repro.launch.fleet import share_k_grid
        return share_k_grid(
            expand_sweep("fed.k0=15,16", base=_base()))

    def test_packed_matches_serial_and_shares(self):
        from repro.launch.fleet import run_fleet
        packed = run_fleet(points=self._points(), packed=True,
                           verbose=False)
        serial = run_fleet(points=self._points(), packed=False,
                           verbose=False)
        assert packed.compile_count == 1          # one bucket signature
        assert serial.compile_count == 1
        assert packed.shared_count == 1
        p = {r.label: r for r in packed.points}
        s = {r.label: r for r in serial.points}
        assert set(p) == set(s)
        for label in p:
            assert p[label].final_loss == s[label].final_loss

    def test_leaderboard_and_csv(self, tmp_path):
        from repro.launch.fleet import run_fleet, CSV_FIELDS
        res = run_fleet(points=self._points(), packed=False, verbose=False)
        board = res.leaderboard()
        assert "k0=15" in board and "k0=16" in board
        out = tmp_path / "fleet.csv"
        res.to_csv(str(out))
        import csv
        with open(out) as f:
            rows = list(csv.DictReader(f))
        assert len(rows) == 2
        assert tuple(rows[0]) == CSV_FIELDS
        assert {r["label"] for r in rows} == {"k0=15", "k0=16"}

    def test_empty_sweep_raises(self):
        from repro.launch.fleet import run_fleet
        with pytest.raises((ValueError, SpecValidationError)):
            run_fleet(points=[], packed=True)

    def test_share_k_grid_pins_max_anchor(self):
        from repro.launch.fleet import share_k_grid
        pts = share_k_grid(expand_sweep("fed.k0=4,8,6", base=_base()))
        assert all(p.spec.fed.k_grid0 == 8 for p in pts)
        assert all(p.spec.fed.k_quantize for p in pts)

    def test_train_cli_sweep_smoke(self, capsys, tmp_path):
        from repro.launch import train
        csv_path = str(tmp_path / "sweep.csv")
        train.main([
            "--rounds", "2",
            "--set", "data.clients=8", "--set", "fed.clients_per_round=4",
            "--set", "fed.batch_size=4",
            "--set", "data.samples_per_client=8",
            "--set", "data.seq_len=16",
            "--set", "fed.k_schedule=fixed",
            "--sweep", "fed.k0=7,8", "--share-k-grid",
            "--sweep-csv", csv_path])
        out = capsys.readouterr().out
        assert "fleet:" in out and "k0=7" in out
        import os
        assert os.path.exists(csv_path)
