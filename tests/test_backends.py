"""Execution-backend tests (DESIGN.md §7): MeshBackend on a 1x1 host mesh is
numerically equivalent to LocalBackend for both strategies — with server
optimizers and robust aggregators — the sharded Pallas aggregation matches
``aggregators.mean``, the strategies module is a true shim over the backend
round core, and the engine's executable registry counts compiles exactly.

Parallel parity is asserted bitwise (same vmap fan-out, only sharding
annotations differ); the sequential streaming path re-associates the
weighted sum (per-group scan + group sum vs a single einsum), so the mean
aggregator is held to the same rtol regime as the reference-loop loss
parity in test_engine.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, get_paper_task
from repro.configs.base import FedConfig
from repro.core import FedAvgTrainer, RuntimeModel
from repro.core.engine import (LocalBackend, MeshBackend, RoundEngine,
                               aggregators)
from repro.data import make_paper_task, pipeline
from repro.distributed.strategies import make_fed_train_step
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh
from repro.models import registry, small


@pytest.fixture(scope="module")
def femnist_setup():
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=16, samples_per_client=30)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    return task, data, loss_fn, params


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh()


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def trees_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def run_trainer(femnist_setup, backend, rounds=8, **fed_kw):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=16, clients_per_round=6, rounds=rounds,
                    k0=4, eta0=0.3, batch_size=8, k_schedule="fixed",
                    seed=0, **fed_kw)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt, backend=backend)
    tr.run(rounds)
    return tr


# ---------------------------------------------------------------------------
# parity: MeshBackend (1x1 mesh) == LocalBackend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fed_kw", [
    dict(),                                                       # plain FedAvg
    dict(server_optimizer="fedavgm", server_lr=0.5,
         aggregator="trimmed_mean"),    # acceptance: non-avg server + robust
    dict(server_optimizer="fedyogi", server_lr=0.1,
         aggregator="median"),
])
def test_mesh_parallel_parity(femnist_setup, host_mesh, fed_kw):
    """Parallel strategy on a degenerate mesh is bitwise the local engine."""
    local = run_trainer(femnist_setup, None, **fed_kw)
    mesh = run_trainer(femnist_setup,
                       MeshBackend(host_mesh, strategy="parallel"), **fed_kw)
    assert trees_equal(local.params, mesh.params)
    np.testing.assert_allclose(local.history.train_loss,
                               mesh.history.train_loss, rtol=1e-6)
    assert mesh.compile_count == 1


@pytest.mark.parametrize("fed_kw,tol", [
    # streaming weighted sum re-associates the mean contraction
    (dict(), dict(rtol=2e-5, atol=1e-6)),
    # robust aggregators materialise the client stack -> same values
    (dict(server_optimizer="fedavgm", server_lr=0.5,
          aggregator="trimmed_mean"), dict(rtol=0, atol=0)),
    (dict(server_optimizer="fedyogi", server_lr=0.1,
          aggregator="median"), dict(rtol=0, atol=0)),
])
def test_mesh_sequential_parity(femnist_setup, host_mesh, fed_kw, tol):
    local = run_trainer(femnist_setup, None, **fed_kw)
    mesh = run_trainer(
        femnist_setup,
        MeshBackend(host_mesh, strategy="sequential", groups=2), **fed_kw)
    trees_close(local.params, mesh.params, **tol)


def test_mesh_prefetched_buckets_match_sync(femnist_setup, host_mesh):
    """device_put-on-the-prefetch-thread placement changes nothing."""
    kw = dict(server_optimizer="fedavgm", server_lr=0.5)
    bg = run_trainer(femnist_setup,
                     MeshBackend(host_mesh, strategy="parallel"),
                     prefetch=True, **kw)
    sync = run_trainer(femnist_setup,
                       MeshBackend(host_mesh, strategy="parallel"),
                       prefetch=False, **kw)
    assert trees_equal(bg.params, sync.params)


# ---------------------------------------------------------------------------
# sharded Pallas aggregation
# ---------------------------------------------------------------------------

def test_sharded_fedavg_reduce_matches_mean(host_mesh):
    rng = np.random.default_rng(0)
    stack = {"w": jnp.asarray(rng.normal(size=(8, 33, 7)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(8, 5000)).astype(np.float32))}
    w = jnp.asarray((rng.random(8) + 0.1).astype(np.float32))
    w = w / w.sum()
    ref = aggregators.weighted_mean(stack, w)
    out = kops.fedavg_reduce_tree_sharded(stack, w, mesh=host_mesh,
                                          client_axes=("data",))
    trees_close(out, ref, rtol=1e-6, atol=1e-6)


def test_mesh_kernel_avg_trains_and_matches_mean(femnist_setup, host_mesh):
    """aggregator='kernel' through the mesh path == mean aggregation (fp tol)."""
    import dataclasses
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=16, clients_per_round=6, rounds=4, k0=3,
                    eta0=0.3, batch_size=8, k_schedule="fixed", seed=0)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr_k = FedAvgTrainer(loss_fn, params, data,
                         dataclasses.replace(fed, aggregator="kernel"), rt,
                         backend=MeshBackend(host_mesh, strategy="parallel"))
    tr_m = FedAvgTrainer(loss_fn, params, data, fed, rt)
    tr_k.run(4)
    tr_m.run(4)
    trees_close(tr_k.params, tr_m.params, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# pinned output shardings: no per-bucket canonicalising device_put
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,groups", [("parallel", 1),
                                             ("sequential", 2)])
def test_bucket_outputs_pinned_to_param_sharding(femnist_setup, host_mesh,
                                                 strategy, groups):
    """The bucket executable's params output carries the backend's param
    sharding (constrain_update), so the next bucket's place_params is the
    no-op fast path — not a resharding transfer (PR-2 ROADMAP item)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    task, data, loss_fn, params = femnist_setup
    # raw PartitionSpecs on one strategy, pre-built NamedShardings on the
    # other — constrain_update must accept both param_specs flavours
    if strategy == "parallel":
        specs = jax.tree.map(lambda _: P(), params)
    else:
        specs = jax.tree.map(lambda _: NamedSharding(host_mesh, P()), params)
    backend = MeshBackend(host_mesh, strategy=strategy, groups=groups,
                          param_specs=specs)
    engine = RoundEngine(loss_fn, backend=backend)
    state = engine.init_server_state(params)
    rng = np.random.default_rng(0)
    out = backend.place_params(params)
    for _ in range(2):
        bb = pipeline.bucket_batches(rng, data, n_rounds=2, k=3,
                                     clients_per_round=6, batch_size=8)
        etas = np.full(2, 0.3, np.float32)
        out, _, _, state = engine.run_bucket(out, bb.batches, bb.weights,
                                             etas, bb.active, state)
    leaves = jax.tree.leaves(out)
    spec_leaves = [P()] * len(leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        target = NamedSharding(host_mesh, spec)
        assert leaf.sharding.is_equivalent_to(target, leaf.ndim)
    # place_params on already-pinned outputs returns the same buffers —
    # the per-bucket device_put is gone
    placed = backend.place_params(out)
    for a, b in zip(jax.tree.leaves(placed), leaves):
        assert a is b


# ---------------------------------------------------------------------------
# strategies shim delegates to the backend round core
# ---------------------------------------------------------------------------

def _lm_round_inputs(cfg, n=4, k=2, b=2, s=16, groups=None):
    rng = np.random.default_rng(0)
    lead = (groups, n // groups, k, b) if groups else (n, k, b)
    tokens = rng.integers(0, cfg.vocab_size, size=lead + (s,), dtype=np.int32)
    w = np.full(lead[:-2], 1.0 / n, np.float32)
    return {"tokens": jnp.asarray(tokens)}, jnp.asarray(w)


def test_strategies_shim_matches_engine_round(femnist_setup, host_mesh):
    """make_fed_train_step == the engine's own round core on the same batch
    (the strategies module carries no local-SGD/aggregation logic anymore)."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(jax.random.PRNGKey(0), cfg)
    loss_fn = registry.loss_fn(cfg, moe_path="dense")
    batches, w = _lm_round_inputs(cfg)
    eta = jnp.float32(0.05)

    step = make_fed_train_step(cfg, strategy="parallel", remat=False,
                               moe_path="dense")
    got_p, got_l = jax.jit(step)(params, batches, w, eta)

    engine = RoundEngine(lambda p, b: loss_fn(p, b), backend=LocalBackend())
    want_p, firsts, _, _ = jax.jit(engine.round_core)(params, batches, w,
                                                      eta, ())
    trees_close(got_p, want_p, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(got_l), float(np.mean(firsts)),
                               rtol=1e-6)


def test_strategies_sequential_shim_runs_grouped(femnist_setup):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(jax.random.PRNGKey(0), cfg)
    batches, w = _lm_round_inputs(cfg, groups=2)
    step = make_fed_train_step(cfg, strategy="sequential", remat=False,
                               moe_path="dense", acc_dtype=jnp.float32)
    new_p, loss = jax.jit(step)(params, batches, w, jnp.float32(0.05))
    assert np.isfinite(float(loss))
    # params actually moved
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_p)))
    assert moved


# ---------------------------------------------------------------------------
# explicit executable registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend_fn", [
    lambda mesh: None,
    lambda mesh: MeshBackend(mesh, strategy="parallel"),
])
def test_compile_registry_counts_exactly(femnist_setup, host_mesh,
                                         backend_fn):
    task, data, loss_fn, params = femnist_setup
    engine = RoundEngine(loss_fn, backend=backend_fn(host_mesh))
    state = engine.init_server_state(params)
    rng = np.random.default_rng(0)

    def bucket(n_rounds, k):
        bb = pipeline.bucket_batches(rng, data, n_rounds=n_rounds, k=k,
                                     clients_per_round=6, batch_size=8)
        etas = np.full(n_rounds, 0.3, np.float32)
        return bb, etas

    assert engine.compile_count == 0
    for i, (b, k) in enumerate([(2, 3), (2, 3), (4, 3), (2, 2)]):
        bb, etas = bucket(b, k)
        params, _, _, state = engine.run_bucket(
            params, bb.batches, bb.weights, etas, bb.active, state)
    # (2,3) reused its executable; (4,3) and (2,2) are new signatures
    assert engine.compile_count == 3
    assert engine.dispatch_count == 4
