"""Public API tests: ExperimentSpec, plugin registries, FederatedExperiment.

The load-bearing contract is the build-parity matrix: an experiment built
from a JSON-round-tripped spec must train bitwise-identically to a
directly-constructed FedAvgTrainer, across backends x transports x
samplers (DESIGN.md §9)."""
import json
import os
import warnings

import jax
import numpy as np
import pytest

from repro.api import (ExperimentSpec, FederatedExperiment,
                       SpecValidationError, build)
from repro.api.registries import (AGGREGATOR_REGISTRY, BACKEND_REGISTRY,
                                  SAMPLER_REGISTRY, TRANSPORT_REGISTRY,
                                  register_aggregator)
from repro.configs import get_paper_task
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel, make_eval_fn
from repro.core.engine import MeshBackend
from repro.core.engine.trainer import History
from repro.data import make_paper_task
from repro.models import small


# ---------------------------------------------------------------------------
# spec serialization / overrides / validation
# ---------------------------------------------------------------------------

def _nondefault_spec() -> ExperimentSpec:
    return ExperimentSpec().with_overrides(
        "data.kind=paper", "data.task=femnist", "data.clients=12",
        "fed.rounds=8", "fed.clients_per_round=4", "fed.k0=3",
        "fed.k_schedule=rounds", "fed.eta0=0.3", "fed.batch_size=4",
        "sampler.name=fixed_cohort", "sampler.cohort=[0,2,5,7]",
        "transport.name=int8", "backend.name=mesh",
        "backend.strategy=sequential", "runtime.beta_seconds=0.05")


def test_spec_json_roundtrip_equality():
    spec = _nondefault_spec()
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    # tuple fields survive the json list detour
    assert again.sampler.cohort == (0, 2, 5, 7)
    # and the round trip is a fixed point
    assert again.to_json() == spec.to_json()


def test_spec_file_roundtrip(tmp_path):
    spec = _nondefault_spec()
    path = os.path.join(tmp_path, "spec.json")
    spec.save(path)
    assert ExperimentSpec.load(path) == spec


def test_from_dict_rejects_unknown_keys_aggregated():
    d = ExperimentSpec().as_dict()
    d["fed"]["warp_factor"] = 9
    d["mystery"] = {}
    with pytest.raises(SpecValidationError) as ei:
        ExperimentSpec.from_dict(d)
    msg = str(ei.value)
    assert "fed.warp_factor" in msg and "mystery" in msg
    assert len(ei.value.errors) == 2


def test_with_overrides_types_and_errors():
    spec = ExperimentSpec().with_overrides(
        "fed.k0=4", "fed.eta0=0.25", "fed.k_quantize=true",
        "transport.name=topk", "sampler.cohort=null")
    assert spec.fed.k0 == 4 and isinstance(spec.fed.k0, int)
    assert spec.fed.eta0 == 0.25
    assert spec.fed.k_quantize is True
    assert spec.transport.name == "topk"
    assert spec.sampler.cohort is None
    with pytest.raises(SpecValidationError) as ei:
        ExperimentSpec().with_overrides("fed.nope=1", "bogus.k=2",
                                        "fed.k0=notanint")
    assert len(ei.value.errors) == 3


def test_validate_aggregates_all_errors():
    spec = ExperimentSpec().with_overrides(
        "fed.k_schedule=warp", "fed.aggregator=meen", "fed.rounds=0",
        "transport.topk_frac=7")
    with pytest.raises(SpecValidationError) as ei:
        spec.validate()
    msg = str(ei.value)
    for frag in ("fed.k_schedule", "fed.aggregator", "fed.rounds",
                 "transport.topk_frac"):
        assert frag in msg
    # did-you-mean rides through the registry error
    assert "mean" in msg


def test_validate_transport_needs_linear_aggregator():
    spec = ExperimentSpec().with_overrides("transport.name=int8",
                                           "fed.aggregator=median")
    with pytest.raises(SpecValidationError, match="linear"):
        spec.validate()


def test_validate_and_override_downlink_field():
    """transport.downlink resolves through the transport registry and rides
    dotted-path overrides; robust aggregators stay legal (downlink only
    changes the broadcast, DESIGN.md §8.6)."""
    spec = ExperimentSpec().with_overrides("transport.downlink=int8",
                                           "fed.aggregator=median")
    assert spec.transport.downlink == "int8"
    spec.validate()
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    with pytest.raises(SpecValidationError, match="transport.downlink"):
        ExperimentSpec().with_overrides(
            "transport.downlink=int9").validate()


def test_validate_cohort_length():
    spec = ExperimentSpec().with_overrides(
        "sampler.name=fixed_cohort", "sampler.cohort=[1,2]",
        "fed.clients_per_round=4")
    with pytest.raises(SpecValidationError, match="cohort"):
        spec.validate()


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_did_you_mean_errors():
    with pytest.raises(KeyError, match="Did you mean 'mean'"):
        AGGREGATOR_REGISTRY.get("meen")
    with pytest.raises(KeyError, match="Did you mean 'fixed_cohort'"):
        SAMPLER_REGISTRY.get("fixed_cohrt")
    with pytest.raises(KeyError, match="Available: local, mesh"):
        BACKEND_REGISTRY.get("tpu-pod")


def test_registry_lists_builtins():
    assert set(AGGREGATOR_REGISTRY.available()) >= {
        "mean", "kernel", "median", "trimmed_mean"}
    assert set(TRANSPORT_REGISTRY.available()) >= {
        "none", "int8", "int8x2", "topk"}
    assert set(SAMPLER_REGISTRY.available()) >= {
        "uniform", "weighted", "fixed_cohort", "availability"}


def test_register_custom_aggregator_resolves_everywhere():
    from repro.core.engine.aggregators import get_aggregator, weighted_mean

    name = "test_double_mean"
    register_aggregator(name, lambda **kw: (
        lambda cp, w: jax.tree.map(lambda x: 2.0 * x,
                                   weighted_mean(cp, w))))
    try:
        agg = get_aggregator(name)
        stack = {"p": np.ones((3, 2), np.float32)}
        out = agg(stack, np.full(3, 1 / 3, np.float32))
        np.testing.assert_allclose(np.asarray(out["p"]), 2.0, rtol=1e-6)
        assert name in AGGREGATOR_REGISTRY.available()
    finally:
        AGGREGATOR_REGISTRY._entries.pop(name, None)


# ---------------------------------------------------------------------------
# build parity: from_json(to_json(spec)) == direct FedAvgTrainer, bitwise
# ---------------------------------------------------------------------------

def _direct_trainer(spec: ExperimentSpec):
    """Hand-constructed trainer for a paper-task spec (what a user would
    have written pre-API)."""
    task = get_paper_task(spec.data.task)
    data = make_paper_task(spec.data.task,
                           np.random.default_rng(spec.data.seed),
                           num_clients=spec.data.clients,
                           samples_per_client=spec.data.samples_per_client)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(spec.fed.seed), task)
    fed = FedConfig(total_clients=spec.data.clients,
                    clients_per_round=spec.fed.clients_per_round,
                    rounds=spec.fed.rounds, k0=spec.fed.k0,
                    eta0=spec.fed.eta0, batch_size=spec.fed.batch_size,
                    loss_window=spec.fed.loss_window,
                    k_schedule=spec.fed.k_schedule,
                    transport=spec.transport.name,
                    sampler=spec.sampler.name, cohort=spec.sampler.cohort,
                    seed=spec.fed.seed)
    rt = RuntimeModel(task.model_size_mb,
                      RuntimeModelConfig(beta_seconds=0.05),
                      fed.clients_per_round)
    backend = None
    if spec.backend.name == "mesh":
        mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
        backend = MeshBackend(mesh, strategy=spec.backend.strategy)
    eval_fn = (make_eval_fn(loss_fn, data) if spec.fed.eval_every else None)
    return FedAvgTrainer(loss_fn, params, data, fed, rt, eval_fn=eval_fn,
                         backend=backend)


@pytest.mark.parametrize("backend", ["local", "mesh"])
@pytest.mark.parametrize("transport", ["none", "int8"])
@pytest.mark.parametrize("sampler", ["uniform", "fixed_cohort"])
def test_build_matches_direct_construction_bitwise(backend, transport,
                                                   sampler):
    """The ISSUE-4 acceptance matrix: {local, mesh-parallel} x {none, int8}
    x {uniform, fixed_cohort}, 8 rounds, bitwise history + params."""
    spec = ExperimentSpec().with_overrides(
        "data.kind=paper", "data.task=femnist", "data.clients=10",
        "data.samples_per_client=20", "fed.rounds=8",
        "fed.clients_per_round=4", "fed.k0=3", "fed.k_schedule=rounds",
        "fed.eta0=0.3", "fed.batch_size=4", "fed.loss_window=5",
        f"backend.name={backend}", f"transport.name={transport}",
        f"sampler.name={sampler}", "runtime.beta_seconds=0.05")
    spec = ExperimentSpec.from_json(spec.to_json())     # serialization detour
    exp = build(spec)
    h = exp.run()
    tr = _direct_trainer(spec)
    h2 = tr.run(8)
    assert h.as_dict() == h2.as_dict()
    for a, b in zip(jax.tree.leaves(exp.params), jax.tree.leaves(tr.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # transport EF state agrees too (per-client slots for fixed cohorts)
    for a, b in zip(jax.tree.leaves(exp.trainer.engine.transport_state),
                    jax.tree.leaves(tr.engine.transport_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# experiment facade: checkpoint embeds the spec
# ---------------------------------------------------------------------------

def _small_spec(**over):
    base = ExperimentSpec().with_overrides(
        "data.kind=paper", "data.task=femnist", "data.clients=10",
        "data.samples_per_client=20", "fed.rounds=8",
        "fed.clients_per_round=4", "fed.k0=3", "fed.k_schedule=rounds",
        "fed.eta0=0.3", "fed.batch_size=4", "fed.loss_window=5",
        "runtime.beta_seconds=0.05")
    return base.with_overrides(*[f"{k}={v}" for k, v in over.items()])


def test_experiment_save_embeds_spec_and_restore_rebuilds(tmp_path):
    spec = _small_spec(**{"transport.name": "int8"})
    exp = build(spec)
    exp.run(rounds=4)
    path = os.path.join(tmp_path, "ckpt")
    exp.save(path)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert ExperimentSpec.from_dict(meta["spec"]) == spec

    # restore rebuilds the exact trainer and continues bitwise: compare
    # against one uninterrupted 8-round run
    resumed = FederatedExperiment.restore(path)
    assert resumed.spec == spec
    resumed.trainer.run(8, resume=True)
    straight = build(spec)
    straight.run()
    assert resumed.history.as_dict() == straight.history.as_dict()
    for a, b in zip(jax.tree.leaves(resumed.params),
                    jax.tree.leaves(straight.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_without_spec_raises(tmp_path):
    spec = _small_spec()
    exp = build(spec)
    exp.run(rounds=2)
    path = os.path.join(tmp_path, "ckpt")
    exp.trainer.save_state(path)            # no embedded spec
    with pytest.raises(ValueError, match="no embedded spec"):
        FederatedExperiment.restore(path)


# ---------------------------------------------------------------------------
# deprecation shim + History schema drift
# ---------------------------------------------------------------------------

def test_use_kernel_avg_deprecated_but_resolves():
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=8, samples_per_client=10)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    fed = FedConfig(total_clients=8, clients_per_round=3, rounds=2, k0=2,
                    eta0=0.3, batch_size=4, loss_window=3)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 3)
    with pytest.warns(DeprecationWarning, match="use_kernel_avg"):
        tr = FedAvgTrainer(loss_fn, params, data, fed, rt,
                           use_kernel_avg=True)
    assert tr.engine.compile_count == 0     # built fine, kernel aggregator


def test_make_round_fn_use_kernel_avg_deprecated():
    from repro.core import make_round_fn
    task = get_paper_task("femnist")
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    with pytest.warns(DeprecationWarning, match="use_kernel_avg"):
        make_round_fn(loss_fn, use_kernel_avg=False)


def test_history_from_dict_warns_on_unknown_fields():
    d = History().as_dict()
    d["rounds"] = [1, 2]
    d["a_new_metric"] = [0.5, 0.6]
    with pytest.warns(UserWarning, match="a_new_metric"):
        h = History.from_dict(d)
    assert h.rounds == [1, 2]
    assert not hasattr(h, "a_new_metric")
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # clean dicts stay silent
        History.from_dict(History().as_dict())
