"""FedAvg engine integration tests (CPU, small synthetic tasks)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_task
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel, make_eval_fn, make_round_fn
from repro.data import make_paper_task
from repro.models import small


@pytest.fixture(scope="module")
def femnist_setup():
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=20, samples_per_client=40)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    return task, data, loss_fn, params


def run(femnist_setup, rounds=15, **fed_kw):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=rounds,
                    k0=6, eta0=0.3, batch_size=8, loss_window=5, **fed_kw)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt,
                       eval_fn=make_eval_fn(loss_fn, data))
    return tr.run(rounds, eval_every=5)


def test_loss_decreases(femnist_setup):
    h = run(femnist_setup)
    assert h.min_train_loss[-1] < h.train_loss[0]
    assert not np.isnan(h.train_loss).any()


def test_k_decay_uses_fewer_steps(femnist_setup):
    h_fixed = run(femnist_setup, k_schedule="fixed")
    h_rounds = run(femnist_setup, k_schedule="rounds")
    assert h_rounds.sgd_steps[-1] < h_fixed.sgd_steps[-1]
    assert h_rounds.wall_clock_s[-1] < h_fixed.wall_clock_s[-1]
    assert h_rounds.k[0] == 6 and h_rounds.k[-1] < 6


def test_dsgd_is_k1(femnist_setup):
    h = run(femnist_setup, k_schedule="dsgd", rounds=5)
    assert all(k == 1 for k in h.k)


def test_fedadam_server_runs(femnist_setup):
    h = run(femnist_setup, rounds=8, server_optimizer="fedadam",
            server_lr=0.01)
    assert np.isfinite(h.train_loss).all()


def test_round_fn_weighted_average_identity():
    """With K=1, eta=0, the round must return the input params exactly."""
    task = get_paper_task("femnist")
    params = small.init_task_model(jax.random.PRNGKey(1), task)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    round_fn, _ = make_round_fn(loss_fn)
    batches = {"x": jnp.ones((4, 1, 2, 784)), "y": jnp.zeros((4, 1, 2), jnp.int32)}
    w = jnp.full((4,), 0.25)
    new, first, last, _ = round_fn(params, batches, w, jnp.float32(0.0), ())
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_kernel_aggregation_matches_einsum():
    task = get_paper_task("femnist")
    params = small.init_task_model(jax.random.PRNGKey(1), task)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    fn_ref, _ = make_round_fn(loss_fn, aggregator="mean")
    fn_ker, _ = make_round_fn(loss_fn, aggregator="kernel")
    rng = jax.random.PRNGKey(2)
    batches = {"x": jax.random.normal(rng, (4, 2, 2, 784)),
               "y": jax.random.randint(rng, (4, 2, 2), 0, 62)}
    w = jnp.array([0.1, 0.2, 0.3, 0.4], jnp.float32)
    eta = jnp.float32(0.1)
    a, fa, _, _ = fn_ref(params, batches, w, eta, ())
    b, fb, _, _ = fn_ker(params, batches, w, eta, ())
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=1e-6)


def test_error_schedule_reacts_to_loss(femnist_setup):
    h = run(femnist_setup, rounds=20, k_schedule="error")
    # after the window warms, K should not exceed K0 and should shrink
    assert max(h.k) == 6
    assert h.k[-1] <= h.k[0]
