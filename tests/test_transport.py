"""Compressed client-delta transport tests (DESIGN.md §8).

Covers: codec roundtrip error bounds, the fused Pallas decompress-reduce
kernels against the decode-then-einsum reference (plain and client-sharded),
server-side error-feedback exactness, transport=none bit-identity with the
historical engine, int8/topk end-to-end parity at matched final loss, both
execution backends, the codec signature in the compile-cache key, and the
runtime model's encoded-uplink accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_task
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel
from repro.core.engine import (DownlinkCodec, IdentityTransport,
                               Int8Transport, MeshBackend, RoundEngine,
                               TopKTransport, get_downlink, get_transport)
from repro.data import make_paper_task, pipeline
from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh
from repro.models import small


@pytest.fixture(scope="module")
def femnist_setup():
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=16, samples_per_client=30)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    return task, data, loss_fn, params


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh()


@pytest.fixture()
def delta_fixture():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(33, 7)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(500,)).astype(np.float32))}
    deltas = jax.tree.map(
        lambda p: jnp.asarray(rng.normal(
            scale=0.01, size=(8,) + p.shape).astype(np.float32)), params)
    w = jnp.asarray((rng.random(8) + 0.1).astype(np.float32))
    return params, deltas, w / w.sum()


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def trees_close(a, b, **kw):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def run_trainer(femnist_setup, transport, backend=None, rounds=8, **fed_kw):
    task, data, loss_fn, params = femnist_setup
    kw = dict(total_clients=16, clients_per_round=6, rounds=rounds, k0=4,
              eta0=0.3, batch_size=8, k_schedule="fixed", seed=0)
    kw.update(fed_kw)
    fed = FedConfig(transport=transport, **kw)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt, backend=backend)
    tr.run(rounds)
    return tr, rt


# ---------------------------------------------------------------------------
# codec roundtrips
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound(delta_fixture):
    """Single-level per-leaf int8: worst-case error one quantisation step."""
    params, deltas, _ = delta_fixture
    t = Int8Transport(levels=1)
    one = jax.tree.map(lambda d: d[0], deltas)
    dec = t.decode(t.encode(one), like=params)
    for x, y in zip(jax.tree.leaves(dec), jax.tree.leaves(one)):
        step = float(jnp.max(jnp.abs(y))) / 127.0
        assert float(jnp.max(jnp.abs(x - y))) <= 0.5 * step + 1e-9


def test_int8x2_roundtrip_tighter_by_residual_level(delta_fixture):
    """The second Q-KV level shrinks worst-case error by another ~127x."""
    params, deltas, _ = delta_fixture
    one = jax.tree.map(lambda d: d[0], deltas)
    e1 = Int8Transport(levels=1)
    e2 = Int8Transport(levels=2)
    d1 = e1.decode(e1.encode(one), like=params)
    d2 = e2.decode(e2.encode(one), like=params)
    for a, b, y in zip(jax.tree.leaves(d1), jax.tree.leaves(d2),
                       jax.tree.leaves(one)):
        err1 = float(jnp.max(jnp.abs(a - y)))
        err2 = float(jnp.max(jnp.abs(b - y)))
        assert err2 < err1 / 20.0


def test_topk_roundtrip_keeps_largest(delta_fixture):
    params, deltas, _ = delta_fixture
    t = TopKTransport(frac=0.1)
    one = jax.tree.map(lambda d: d[0], deltas)
    dec = t.decode(t.encode(one), like=params)
    for x, y in zip(jax.tree.leaves(dec), jax.tree.leaves(one)):
        flat, ref = np.asarray(x).ravel(), np.asarray(y).ravel()
        k = max(1, int(np.ceil(0.1 * ref.size)))
        kept = np.flatnonzero(flat)
        assert len(kept) == k
        # kept entries are exactly the k largest |ref| entries, verbatim
        top = np.argsort(-np.abs(ref))[:k]
        assert set(kept) == set(top)
        np.testing.assert_array_equal(flat[kept], ref[kept])


# ---------------------------------------------------------------------------
# fused decompress-reduce kernels vs decode-then-einsum reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [1, 2])
def test_int8_fused_reduce_matches_reference(delta_fixture, levels):
    params, deltas, w = delta_fixture
    t = Int8Transport(levels=levels)
    payloads = jax.vmap(t.encode)(deltas)
    fused = t.reduce(payloads, w, like=params)
    decoded = jax.vmap(lambda pl: t.decode(pl, like=params))(payloads)
    ref = jax.tree.map(lambda d: jnp.einsum("c,c...->...", w, d), decoded)
    trees_close(fused, ref, rtol=1e-6, atol=1e-7)


def test_int8_fused_reduce_sharded_matches_plain(delta_fixture, host_mesh):
    params, deltas, w = delta_fixture
    t = Int8Transport(levels=2)
    payloads = jax.vmap(t.encode)(deltas)
    plain = t.reduce(payloads, w, like=params)
    sharded = t.with_mesh(host_mesh, ("data",)).reduce(payloads, w,
                                                       like=params)
    trees_close(sharded, plain, rtol=1e-6, atol=1e-7)


def test_topk_scatter_reduce_matches_reference(delta_fixture):
    params, deltas, w = delta_fixture
    t = TopKTransport(frac=0.15)
    payloads = jax.vmap(t.encode)(deltas)
    fused = t.reduce(payloads, w, like=params)
    decoded = jax.vmap(lambda pl: t.decode(pl, like=params))(payloads)
    ref = jax.tree.map(lambda d: jnp.einsum("c,c...->...", w, d), decoded)
    trees_close(fused, ref, rtol=1e-6, atol=1e-7)


def test_topk_duplicate_indices_accumulate():
    """The flat (N*S,) scatter must ADD across clients hitting one slot."""
    vals = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    idx = jnp.asarray([[0, 2], [0, 1]], jnp.int32)
    w = jnp.asarray([0.5, 0.5], jnp.float32)
    out = kops.topk_delta_reduce(vals, idx, w, 4)
    np.testing.assert_allclose(np.asarray(out), [2.0, 2.0, 1.0, 0.0])


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [lambda: Int8Transport(levels=1),
                                lambda: TopKTransport(frac=0.1)])
def test_error_feedback_residual_is_exact(delta_fixture, mk):
    """residual' = sum_c w_c (delta_c + residual) - hat, exactly."""
    params, deltas, w = delta_fixture
    t = mk()
    state = jax.tree.map(
        lambda p: jnp.asarray(np.random.default_rng(1).normal(
            scale=1e-3, size=p.shape).astype(np.float32)), params)
    stack = jax.tree.map(lambda p, d: p[None] + d, params, deltas)
    agg, new_state = jax.jit(
        lambda p, cs, ww, s: t.aggregate(None, p, cs, ww, s))(
            params, stack, w, state)
    # reconstruct the corrected deltas exactly as the codec sees them
    # ((p + d) - p != d in fp, and round-to-nearest is discontinuous)
    corrected = jax.tree.map(lambda cp, p, r: (cp - p[None]) + r[None],
                             stack, params, state)
    hat = t.reduce(jax.vmap(t.encode)(corrected), w, like=params)
    true = jax.tree.map(lambda d: jnp.einsum("c,c...->...", w, d), corrected)
    trees_close(new_state, jax.tree.map(jnp.subtract, true, hat),
                rtol=1e-6, atol=1e-8)
    trees_close(agg, jax.tree.map(jnp.add, params, hat),
                rtol=1e-6, atol=1e-8)


def test_int8_error_feedback_recovers_loss(femnist_setup):
    """EF keeps single-level int8 at the uncompressed final loss (the
    'matched final loss' acceptance regime)."""
    base, _ = run_trainer(femnist_setup, "none")
    int8, _ = run_trainer(femnist_setup, "int8")
    assert abs(int8.history.train_loss[-1]
               - base.history.train_loss[-1]) < 5e-3


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_transport_none_is_bitwise_identical(femnist_setup):
    """FedConfig(transport='none') routes through the historical bucket
    program — params and history bitwise equal to the default config."""
    a, _ = run_trainer(femnist_setup, "none",
                       server_optimizer="fedavgm", server_lr=0.5)
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=16, clients_per_round=6, rounds=8, k0=4,
                    eta0=0.3, batch_size=8, k_schedule="fixed", seed=0,
                    server_optimizer="fedavgm", server_lr=0.5)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    b = FedAvgTrainer(loss_fn, params, data, fed, rt)
    b.run(8)
    assert trees_equal(a.params, b.params)
    assert a.history.train_loss == b.history.train_loss


def test_identity_transport_matches_engine_bitwise(femnist_setup):
    """The explicit identity codec (through the transport-threaded bucket
    program) reproduces the transport-less engine bitwise — the protocol
    adds no arithmetic."""
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=16, clients_per_round=6, rounds=6, k0=4,
                    eta0=0.3, batch_size=8, k_schedule="fixed", seed=0,
                    aggregator="trimmed_mean")
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    base = FedAvgTrainer(loss_fn, params, data, fed, rt)
    base.run(6)
    fed_t = FedConfig(total_clients=16, clients_per_round=6, rounds=6, k0=4,
                      eta0=0.3, batch_size=8, k_schedule="fixed", seed=0,
                      aggregator="trimmed_mean",
                      transport=IdentityTransport())
    ident = FedAvgTrainer(loss_fn, params, data, fed_t, rt)
    ident.run(6)
    assert trees_equal(base.params, ident.params)


@pytest.mark.parametrize("transport", ["int8", "int8x2", "topk"])
def test_transport_mesh_parallel_bitwise_parity(femnist_setup, host_mesh,
                                                transport):
    """Compressed paths on a degenerate mesh == local (annotations + a
    1-shard psum only)."""
    local, _ = run_trainer(femnist_setup, transport)
    mesh, _ = run_trainer(femnist_setup, transport,
                          backend=MeshBackend(host_mesh,
                                              strategy="parallel"))
    assert trees_equal(local.params, mesh.params)
    assert mesh.compile_count == 1


@pytest.mark.parametrize("transport", ["int8", "topk"])
def test_transport_sequential_single_round_parity(femnist_setup, host_mesh,
                                                  transport):
    """One round of the streaming sequential codec path matches the local
    path to sum-re-association tolerance. (Multi-round numeric parity is
    out of contract: round-to-nearest is discontinuous, so a one-ulp sum
    difference can flip an int8 code / top-k pick and the paths then
    legitimately diverge — DESIGN.md §8.)"""
    local, _ = run_trainer(femnist_setup, transport, rounds=1)
    seq, _ = run_trainer(femnist_setup, transport, rounds=1,
                         backend=MeshBackend(host_mesh,
                                             strategy="sequential", groups=2))
    trees_close(local.params, seq.params, rtol=2e-5, atol=1e-6)


def test_identity_transport_sequential_keeps_robust_aggregator(femnist_setup,
                                                               host_mesh):
    """The identity codec on the sequential strategy must still run the
    configured (robust) aggregator — not silently stream a mean."""
    task, data, loss_fn, params = femnist_setup
    kw = dict(total_clients=16, clients_per_round=6, rounds=4, k0=3,
              eta0=0.3, batch_size=8, k_schedule="fixed", seed=0,
              aggregator="median")
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    legacy = FedAvgTrainer(loss_fn, params, data, FedConfig(**kw), rt,
                           backend=MeshBackend(host_mesh,
                                               strategy="sequential",
                                               groups=2))
    legacy.run(4)
    ident = FedAvgTrainer(loss_fn, params, data,
                          FedConfig(transport=IdentityTransport(), **kw), rt,
                          backend=MeshBackend(host_mesh,
                                              strategy="sequential",
                                              groups=2))
    ident.run(4)
    assert trees_equal(legacy.params, ident.params)


@pytest.mark.parametrize("transport", ["int8", "topk"])
def test_transport_sequential_trains(femnist_setup, host_mesh, transport):
    tr, _ = run_trainer(femnist_setup, transport, rounds=8,
                        backend=MeshBackend(host_mesh,
                                            strategy="sequential", groups=2))
    h = tr.history.train_loss
    assert np.isfinite(h).all() and h[-1] < h[0]


def test_transport_rejects_robust_aggregators(femnist_setup):
    _, _, loss_fn, _ = femnist_setup
    with pytest.raises(ValueError, match="linear"):
        RoundEngine(loss_fn, aggregator="median", transport="int8")
    with pytest.raises(ValueError, match="linear"):
        RoundEngine(loss_fn, aggregator="trimmed_mean", transport="topk")


def test_compile_key_carries_codec_signature(femnist_setup):
    """Same input signature, different codec -> different registry keys;
    the codec signature is the key's leading component."""
    task, data, loss_fn, params = femnist_setup
    state_args = {}
    for name in ("int8", "topk"):
        engine = RoundEngine(loss_fn, transport=name)
        state = engine.init_server_state(params)
        rng = np.random.default_rng(0)
        bb = pipeline.bucket_batches(rng, data, n_rounds=2, k=3,
                                     clients_per_round=6, batch_size=8)
        etas = np.full(2, 0.3, np.float32)
        engine.run_bucket(params, bb.batches, bb.weights, etas, bb.active,
                          state)
        assert engine.compile_count == 1
        (key,) = engine._executables.keys()
        assert key[0] == engine.transport.signature()
        state_args[name] = key
    assert state_args["int8"][0] != state_args["topk"][0]
    # identical data signatures — only the codec component differs
    assert state_args["int8"][2] == state_args["topk"][2]


# ---------------------------------------------------------------------------
# topk tiny-leaf edges: k clamped to [1, leaf_size]
# ---------------------------------------------------------------------------

def test_topk_k_clamped_to_leaf_bounds():
    t = TopKTransport(frac=0.1)
    assert t._k(1) == 1          # ceil(0.1) would keep the leaf, not drop it
    assert t._k(3) == 1
    assert t._k(0) == 0          # empty leaf ships an empty payload
    full = TopKTransport(frac=1.0)
    for size in (1, 2, 7, 1000):
        assert full._k(size) == size     # never past the leaf itself


@pytest.mark.parametrize("leaf", [jnp.asarray(3.5),          # scalar
                                  jnp.asarray([2.0]),        # 1-element
                                  jnp.asarray([[-1.5]])])    # 1-element 2d
def test_topk_roundtrip_tiny_leaves_exact(leaf):
    """Tiny leaves must survive the wire verbatim: k clamps to 1, so the
    single coordinate IS the payload (frac would otherwise round k to 0
    and silently drop the leaf)."""
    like = {"w": leaf, "big": jnp.arange(20, dtype=jnp.float32)}
    t = TopKTransport(frac=0.05)
    dec = t.decode(t.encode(like), like=like)
    np.testing.assert_array_equal(np.asarray(dec["w"]), np.asarray(leaf))
    assert t.encoded_bits({"w": leaf}) == 64
    # and the engine-side reduce path agrees
    stack = jax.tree.map(lambda l: jnp.stack([l, 2 * l]), like)
    red = t.reduce(jax.vmap(t.encode)(stack),
                   jnp.asarray([0.5, 0.5], jnp.float32), like=like)
    np.testing.assert_allclose(np.asarray(red["w"]),
                               1.5 * np.asarray(leaf), rtol=1e-6)


def test_topk_empty_leaf_roundtrip():
    like = {"empty": jnp.zeros((0,), jnp.float32),
            "w": jnp.asarray([1.0, -2.0])}
    t = TopKTransport(frac=0.5)
    payload = t.encode(like)
    assert payload[0]["v"].shape == (0,)           # k == 0 on the empty leaf
    dec = t.decode(payload, like=like)
    assert dec["empty"].shape == (0,)
    # k = ceil(.5 * 2) = 1: the largest-|.| coordinate survives verbatim
    np.testing.assert_array_equal(np.asarray(dec["w"]), [0.0, -2.0])


# ---------------------------------------------------------------------------
# downlink: codec state machine, fused decode-apply, engine integration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("levels", [1, 2])
def test_int8_decode_apply_fused_matches_decode_then_add(delta_fixture,
                                                         levels):
    params, deltas, _ = delta_fixture
    t = Int8Transport(levels=levels)
    one = jax.tree.map(lambda d: d[0], deltas)
    payload = t.encode(one)
    fused = t.decode_apply(payload, params)
    ref = jax.tree.map(jnp.add, params, t.decode(payload, like=params))
    trees_close(fused, ref, rtol=1e-6, atol=1e-7)


def test_int8_decode_apply_sharded_matches_plain(delta_fixture, host_mesh):
    params, deltas, _ = delta_fixture
    t = Int8Transport(levels=2)
    one = jax.tree.map(lambda d: d[0], deltas)
    payload = t.encode(one)
    plain = t.decode_apply(payload, params)
    sharded = t.with_mesh(host_mesh, ("data",)).decode_apply(payload, params)
    trees_close(sharded, plain, rtol=1e-6, atol=1e-7)


def test_topk_decode_apply_matches_decode_then_add(delta_fixture):
    params, deltas, _ = delta_fixture
    t = TopKTransport(frac=0.2)
    one = jax.tree.map(lambda d: d[0], deltas)
    payload = t.encode(one)
    fused = t.decode_apply(payload, params)
    ref = jax.tree.map(jnp.add, params, t.decode(payload, like=params))
    trees_close(fused, ref, rtol=1e-6, atol=1e-7)


def test_downlink_codec_state_machine_and_ef_exact(delta_fixture):
    """Reference-param state machine (DESIGN.md §8.6): round 0 ships a zero
    delta (recon bitwise == params); afterwards ref' == recon and the
    downlink residual is exactly ``(delta + residual) - dec(payload)``."""
    params, deltas, _ = delta_fixture
    dl = DownlinkCodec(Int8Transport(levels=1))
    state = dl.init_state(params)
    assert trees_equal(state["ref"], params)
    recon, state = dl.broadcast(params, state)
    assert trees_equal(recon, params)              # enc(0) decodes to 0
    assert all(not np.asarray(l).any()
               for l in jax.tree.leaves(state["res"]))
    new_params = jax.tree.map(lambda p, d: p + d[0], params, deltas)
    recon2, state2 = dl.broadcast(new_params, state)
    codec = Int8Transport(levels=1)
    delta = jax.tree.map(
        lambda n, r, s: (n - r) + s, new_params, recon, state["res"])
    dec = codec.decode(codec.encode(delta), like=params)
    trees_close(recon2, jax.tree.map(jnp.add, recon, dec),
                rtol=1e-6, atol=1e-8)
    trees_close(state2["res"], jax.tree.map(jnp.subtract, delta, dec),
                rtol=1e-6, atol=1e-8)
    assert trees_equal(state2["ref"], recon2)      # clients hold recon2 now
    # no-EF codec carries no residual buffer
    assert DownlinkCodec(Int8Transport(levels=2,
                                       error_feedback=False)
                         ).init_state(params)["res"] == ()
    with pytest.raises(ValueError, match="none"):
        DownlinkCodec(None)
    assert get_downlink("none") is None and get_downlink(None) is None


def test_downlink_none_keeps_program_bitwise(femnist_setup):
    """FedConfig(downlink='none') must keep the PR-4 compiled round program
    bit-for-bit: identical executable-registry keys, params and history."""
    a, _ = run_trainer(femnist_setup, "int8")
    b, _ = run_trainer(femnist_setup, "int8", downlink="none")
    assert set(a.engine._executables) == set(b.engine._executables)
    assert a.engine._codec_sig == b.engine._codec_sig
    assert trees_equal(a.params, b.params)
    assert a.history.as_dict() == b.history.as_dict()


@pytest.mark.parametrize("downlink", ["int8", "int8x2", "topk"])
def test_downlink_trains_and_charges_wire(femnist_setup, downlink):
    base, _ = run_trainer(femnist_setup, "none")
    comp, _ = run_trainer(femnist_setup, "none", downlink=downlink)
    assert np.isfinite(comp.history.train_loss).all()
    ratio = (base.history.downlink_mbit[-1]
             / comp.history.downlink_mbit[-1])
    assert ratio == pytest.approx(comp.runtime.downlink_compression)
    assert ratio >= 1.9                      # int8x2 ~2x, int8 ~4x, topk 5x
    assert comp.history.uplink_mbit[-1] == \
        pytest.approx(base.history.uplink_mbit[-1])
    assert comp.history.wall_clock_s[-1] < base.history.wall_clock_s[-1]


def test_downlink_int8_error_feedback_recovers_loss(femnist_setup):
    """The matched-final-loss acceptance regime on the broadcast leg: the
    downlink EF residual keeps int8 at the uncompressed final loss."""
    base, _ = run_trainer(femnist_setup, "none")
    comp, _ = run_trainer(femnist_setup, "none", downlink="int8")
    assert abs(comp.history.train_loss[-1]
               - base.history.train_loss[-1]) < 2e-2


@pytest.mark.parametrize("transport,downlink", [("none", "int8"),
                                                ("int8", "int8"),
                                                ("topk", "topk")])
def test_downlink_mesh_parallel_bitwise_parity(femnist_setup, host_mesh,
                                               transport, downlink):
    local, _ = run_trainer(femnist_setup, transport, downlink=downlink)
    mesh, _ = run_trainer(femnist_setup, transport, downlink=downlink,
                          backend=MeshBackend(host_mesh,
                                              strategy="parallel"))
    assert trees_equal(local.params, mesh.params)
    for a, b in zip(jax.tree.leaves(local.engine.downlink_state),
                    jax.tree.leaves(mesh.engine.downlink_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_downlink_sequential_trains(femnist_setup, host_mesh):
    tr, _ = run_trainer(femnist_setup, "int8", downlink="int8",
                        backend=MeshBackend(host_mesh,
                                            strategy="sequential", groups=2))
    h = tr.history.train_loss
    assert np.isfinite(h).all() and h[-1] < h[0]


def test_downlink_works_with_robust_aggregators(femnist_setup):
    """Downlink compression only changes the broadcast every client
    reconstructs identically — the aggregation contract is untouched, so
    robust aggregators stay legal (unlike compressed uplink)."""
    tr, _ = run_trainer(femnist_setup, "none", downlink="int8",
                        aggregator="median")
    assert np.isfinite(tr.history.train_loss).all()


def test_downlink_compile_key_nests_codec_signatures(femnist_setup):
    task, data, loss_fn, params = femnist_setup
    engine = RoundEngine(loss_fn, transport="int8", downlink="int8")
    state = engine.init_server_state(params)
    rng = np.random.default_rng(0)
    bb = pipeline.bucket_batches(rng, data, n_rounds=2, k=3,
                                 clients_per_round=6, batch_size=8)
    etas = np.full(2, 0.3, np.float32)
    engine.run_bucket(params, bb.batches, bb.weights, etas, bb.active, state)
    assert engine.compile_count == 1
    (key,) = engine._executables.keys()
    assert key[0] == (engine.transport.signature(),
                      engine.downlink.signature())
    assert engine.downlink.signature()[0] == "downlink"


# ---------------------------------------------------------------------------
# runtime model: encoded bytes on the wire
# ---------------------------------------------------------------------------

def test_runtime_model_charges_encoded_uplink():
    cfg = RuntimeModelConfig(download_mbps=20, upload_mbps=5,
                             beta_seconds=0.1)
    base = RuntimeModel(40.0, cfg, clients_per_round=10)
    comp = RuntimeModel(40.0, cfg, clients_per_round=10,
                        uplink_compression=4.0)
    c0, c1 = base.round_cost(8), comp.round_cost(8)
    assert c1.uplink_mbit == pytest.approx(c0.uplink_mbit / 4.0)
    assert c1.downlink_mbit == c0.downlink_mbit          # broadcast full |x|
    assert c1.wall_clock_s == pytest.approx(
        c0.wall_clock_s - (40.0 - 10.0) / 5.0)
    # Eq. 5 totals re-derive from the same comm_time source
    assert comp.total_time([8, 8]) == pytest.approx(
        sum(comp.round_cost(8).wall_clock_s for _ in range(2)))


def test_trainer_sets_uplink_compression_and_history(femnist_setup):
    base, rt0 = run_trainer(femnist_setup, "none")
    int8, rt8 = run_trainer(femnist_setup, "int8")
    assert rt0.uplink_compression == 1.0
    # the injected RuntimeModel is never mutated — the trainer owns a
    # compressed copy, so sharing one instance across trainers is safe
    assert rt8.uplink_compression == 1.0
    assert 3.9 < int8.runtime.uplink_compression <= 4.0
    ratio = base.history.uplink_mbit[-1] / int8.history.uplink_mbit[-1]
    assert ratio == pytest.approx(int8.runtime.uplink_compression)
    # modelled wall-clock is cheaper under compression too
    assert int8.history.wall_clock_s[-1] < base.history.wall_clock_s[-1]


def test_runtime_model_charges_encoded_downlink():
    cfg = RuntimeModelConfig(download_mbps=20, upload_mbps=5,
                             beta_seconds=0.1)
    base = RuntimeModel(40.0, cfg, clients_per_round=10)
    comp = RuntimeModel(40.0, cfg, clients_per_round=10,
                        downlink_compression=4.0)
    c0, c1 = base.round_cost(8), comp.round_cost(8)
    assert c1.downlink_mbit == pytest.approx(c0.downlink_mbit / 4.0)
    assert c1.uplink_mbit == c0.uplink_mbit            # uplink untouched
    assert c1.wall_clock_s == pytest.approx(
        c0.wall_clock_s - (40.0 - 10.0) / 20.0)
    assert comp.total_time([8, 8]) == pytest.approx(
        sum(comp.round_cost(8).wall_clock_s for _ in range(2)))


def test_compression_ratio_accounting(delta_fixture):
    params, _, _ = delta_fixture
    n = sum(int(l.size) for l in jax.tree.leaves(params))
    n_leaves = len(jax.tree.leaves(params))
    int8 = Int8Transport(levels=1)
    assert int8.encoded_bits(params) == 8 * n + 32 * n_leaves
    assert int8.nominal_ratio() == 4.0
    assert Int8Transport(levels=2).nominal_ratio() == 2.0
    topk = TopKTransport(frac=0.05)
    assert topk.nominal_ratio() == pytest.approx(10.0)
    assert get_transport("none") is None


# ---------------------------------------------------------------------------
# quantised params_ref store + adaptive downlink (DESIGN.md §10.3-10.4)
# ---------------------------------------------------------------------------

from repro.core.engine import AdaptiveDownlinkCodec  # noqa: E402


def test_q8_ref_store_roundtrip_and_bytes(delta_fixture):
    """ref_store='q8' holds params_ref/residual as two-level int8 + scales:
    ~2 bytes/param held server-side, reconstruction error one second-level
    quantisation step, and the codec signature (compile key) changes."""
    params, _, _ = delta_fixture
    f32 = DownlinkCodec(Int8Transport(levels=1))
    q8 = DownlinkCodec(Int8Transport(levels=1), ref_store="q8")
    assert q8.signature() != f32.signature()
    assert q8.signature()[-1] == "ref:q8"
    st = q8.init_state(params)
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(st["ref"]))
    back = q8.load_tree(st["ref"], like=params)
    for x, y in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
        bound = float(jnp.max(jnp.abs(y))) / 127.0 ** 2
        assert float(jnp.max(jnp.abs(x - y))) <= bound
    assert q8.state_bytes(st) < 0.6 * f32.state_bytes(f32.init_state(params))
    with pytest.raises(ValueError):
        DownlinkCodec(Int8Transport(levels=1), ref_store="fp8")


def test_q8_ref_store_trains_matched_loss(femnist_setup):
    """|dloss| <= 2e-2 vs the f32 ref store, with ~2x less state held."""
    base, _ = run_trainer(femnist_setup, "none", downlink="int8")
    q8, _ = run_trainer(femnist_setup, "none", downlink="int8",
                        downlink_ref="q8")
    assert np.isfinite(q8.history.train_loss).all()
    assert abs(q8.history.train_loss[-1]
               - base.history.train_loss[-1]) < 2e-2
    held_f32 = base.engine.downlink.state_bytes(base.engine.downlink_state)
    held_q8 = q8.engine.downlink.state_bytes(q8.engine.downlink_state)
    assert held_q8 < 0.6 * held_f32
    # same wire bytes: the ref store is a server-memory knob, not a codec
    assert q8.history.downlink_mbit[-1] == \
        pytest.approx(base.history.downlink_mbit[-1])


def test_q8_ref_requires_downlink(femnist_setup):
    task, data, loss_fn, params = femnist_setup
    with pytest.raises(ValueError, match="downlink_ref"):
        RoundEngine(loss_fn, downlink=None, downlink_ref="q8")


def test_adaptive_is_downlink_only():
    assert isinstance(get_downlink("adaptive"), AdaptiveDownlinkCodec)
    with pytest.raises(ValueError, match="downlink-only"):
        get_transport("adaptive")


def test_adaptive_level_policy(delta_fixture):
    """Traced level policy: zero delta skips (0), a real delta ships int8
    (1), a spiked EF residual boosts to int8x2 (2); the lazy decode_into
    matches the server-side eager reconstruction bitwise."""
    params, _, _ = delta_fixture
    dl = AdaptiveDownlinkCodec()
    state = dl.init_state(params)
    ref, payload, recon, state, lvl = dl.encode_broadcast(params, state)
    assert int(lvl) == 0                     # delta == 0 -> ship nothing
    assert trees_equal(recon, params)        # clients keep the old ref
    p2 = jax.tree.map(lambda x: x + 0.05, params)
    ref, payload, recon, st2, lvl = dl.encode_broadcast(p2, state)
    assert int(lvl) == 1
    assert trees_equal(dl.decode_into(payload, ref), recon)
    spiked = {"ref": state["ref"],
              "res": jax.tree.map(jnp.ones_like, params)}
    *_, lvl = dl.encode_broadcast(p2, spiked)
    assert int(lvl) == 2
    assert dl.level_ratios(params)[1] > dl.level_ratios(params)[2] > 1.9


def test_adaptive_downlink_trains_and_charges_per_level(femnist_setup):
    """End-to-end: finite matched loss, per-round levels in {0,1,2}, and
    the skipped first broadcast (ref == init params) charged zero bits."""
    base, _ = run_trainer(femnist_setup, "none")
    tr, _ = run_trainer(femnist_setup, "none", downlink="adaptive")
    assert np.isfinite(tr.history.train_loss).all()
    assert abs(tr.history.train_loss[-1]
               - base.history.train_loss[-1]) < 2e-2
    lv = np.asarray(tr.engine.last_downlink_levels)
    assert set(np.unique(lv)) <= {0, 1, 2}
    assert tr.runtime.downlink_level_ratios is not None
    assert set(tr.runtime.downlink_level_ratios) == {1, 2}
    # round 1: ref == init params -> level 0 -> zero broadcast bits charged
    assert tr.history.downlink_mbit[0] == 0.0
    assert base.history.downlink_mbit[0] > 0.0
    assert tr.history.downlink_mbit[-1] < base.history.downlink_mbit[-1]
    assert tr.history.uplink_mbit[-1] == \
        pytest.approx(base.history.uplink_mbit[-1])


def _mk_trainer(femnist_setup, rounds=6, **fed_kw):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=16, clients_per_round=6, rounds=rounds,
                    k0=4, eta0=0.3, batch_size=8, k_schedule="fixed",
                    seed=0, transport="none", **fed_kw)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    return FedAvgTrainer(loss_fn, params, data, fed, rt)


def test_q8_checkpoint_resume_bitwise(femnist_setup, tmp_path):
    """save/restore with a quantised ref store resumes bitwise: the q8
    leaves round-trip as stored int8 planes, no de/re-quantise cycle."""
    straight = _mk_trainer(femnist_setup, downlink="int8",
                           downlink_ref="q8")
    straight.run(6)
    first = _mk_trainer(femnist_setup, downlink="int8", downlink_ref="q8")
    first.run(3)
    path = str(tmp_path / "q8ck")
    first.save_state(path)
    resumed = _mk_trainer(femnist_setup, downlink="int8",
                          downlink_ref="q8")
    resumed.restore_state(path)
    for a, b in zip(jax.tree.leaves(first.engine.downlink_state),
                    jax.tree.leaves(resumed.engine.downlink_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resumed.run(6, resume=True)
    assert trees_equal(straight.params, resumed.params)
    assert straight.history.as_dict() == resumed.history.as_dict()


def test_f32_checkpoint_converts_into_q8_trainer(femnist_setup, tmp_path):
    """A pre-q8 (f32 ref store) checkpoint restores into a ref_store='q8'
    trainer: the stored f32 trees re-quantise on load and training
    continues — the one legacy conversion that is allowed to be lossy."""
    f32tr = _mk_trainer(femnist_setup, downlink="int8")
    f32tr.run(3)
    path = str(tmp_path / "f32ck")
    f32tr.save_state(path)
    q8tr = _mk_trainer(femnist_setup, downlink="int8", downlink_ref="q8")
    q8tr.restore_state(path)
    st = q8tr.engine.downlink_state
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(st["ref"]))
    back = q8tr.engine.downlink.load_tree(st["ref"], like=q8tr.params)
    f32ref = f32tr.engine.downlink_state["ref"]
    for x, y in zip(jax.tree.leaves(back), jax.tree.leaves(f32ref)):
        bound = float(jnp.max(jnp.abs(y))) / 127.0 ** 2 + 1e-9
        assert float(jnp.max(jnp.abs(x - y))) <= bound
    q8tr.run(6, resume=True)
    assert np.isfinite(q8tr.history.train_loss).all()
