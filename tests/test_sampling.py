"""ClientSampler subsystem tests (DESIGN.md §9.3).

Seed-exactness of the default sampler against the historical stream, the
behavioural contracts of the other policies, and the per-client vs
server-aggregate error-feedback equivalence in the single-client case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core.engine.round import RoundEngine
from repro.core.engine.sampling import (AvailabilitySampler,
                                        FixedCohortSampler, UniformSampler,
                                        WeightedSampler, get_sampler,
                                        make_sampler)
from repro.core.engine.transport import Int8Transport, TopKTransport
from repro.data import make_paper_task, pipeline
from repro.models import small


@pytest.fixture(scope="module")
def data():
    return make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=12, samples_per_client=20)


# ---------------------------------------------------------------------------
# uniform: stream-exact with the historical pipeline draw
# ---------------------------------------------------------------------------

def test_uniform_sampler_seed_exact_vs_legacy_stream(data):
    """UniformSampler must consume draw-for-draw the rng stream of the
    historical sample_clients + client_weights pair — the bitwise parity of
    every pre-sampler run depends on it."""
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    s = UniformSampler()
    for _ in range(20):
        ids_legacy = pipeline.sample_clients(r1, data, 5)
        w_legacy = pipeline.client_weights(data, ids_legacy)
        ids, w = s.round(r2, data, 5)
        np.testing.assert_array_equal(ids_legacy, ids)
        np.testing.assert_array_equal(w_legacy, w)
        assert r1.bit_generator.state == r2.bit_generator.state


def test_bucket_batches_sampler_none_equals_uniform(data):
    kw = dict(n_rounds=4, k=3, clients_per_round=5, batch_size=4)
    a = pipeline.bucket_batches(np.random.default_rng(3), data, **kw)
    b = pipeline.bucket_batches(np.random.default_rng(3), data,
                                sampler=UniformSampler(),
                                round_ids=[1, 2, 3, 4], **kw)
    np.testing.assert_array_equal(a.batches["x"], b.batches["x"])
    np.testing.assert_array_equal(a.batches["y"], b.batches["y"])
    np.testing.assert_array_equal(a.weights, b.weights)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_fixed_cohort_constant_and_ordered(data):
    s = FixedCohortSampler(cohort=(3, 1, 8))
    rng = np.random.default_rng(0)
    for r in range(5):
        ids, w = s.round(rng, data, 3, round_idx=r + 1)
        np.testing.assert_array_equal(ids, [3, 1, 8])
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert s.stateful_cohort
    # default cohort = first n clients
    ids, _ = FixedCohortSampler().round(rng, data, 4)
    np.testing.assert_array_equal(ids, [0, 1, 2, 3])
    with pytest.raises(ValueError, match="cohort has 3"):
        FixedCohortSampler(cohort=(0, 1, 2)).sample(rng, data, 4)
    with pytest.raises(ValueError, match="out of range"):
        FixedCohortSampler(cohort=(0, 99, 2)).sample(rng, data, 3)


def test_weighted_sampler_prefers_large_clients():
    # client 0 owns 10x the data of everyone else
    counts = [200] + [20] * 9
    rng = np.random.default_rng(0)

    class D:
        num_clients = 10
        client_y = [np.zeros(c) for c in counts]

    s = WeightedSampler()
    hits = sum(0 in s.sample(rng, D(), 3) for _ in range(300))
    assert hits > 250        # ~10x inclusion mass => near-certain presence
    ids = s.sample(rng, D(), 3)
    assert len(set(ids.tolist())) == 3          # without replacement


def test_availability_masks_and_zero_weights_shortfall(data):
    rng = np.random.default_rng(0)
    s = AvailabilitySampler(prob=0.25)
    saw_shortfall = False
    for r in range(50):
        ids, w = s.round(rng, data, 6, round_idx=r + 1)
        assert len(ids) == 6 and len(set(ids.tolist())) == 6
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
        if (w == 0).any():
            saw_shortfall = True
    assert saw_shortfall     # p=.25 of 12 clients: shortfalls must occur
    with pytest.raises(ValueError, match="prob"):
        AvailabilitySampler(prob=0.0)


def test_availability_prob_near_zero_never_degenerates(data):
    """prob≈0 regression (the all-offline round): every round hits the
    ``np.flatnonzero(...) == []`` path, which must re-draw a uniform round
    — never pad the whole cohort at weight 0 (a 0/0 weighted mean would
    poison the params with NaN)."""
    rng = np.random.default_rng(0)
    s = AvailabilitySampler(prob=1e-12)
    for r in range(20):
        ids, w = s.round(rng, data, 5, round_idx=r + 1)
        assert len(ids) == 5 and len(set(ids.tolist())) == 5
        assert np.isfinite(w).all() and (w >= 0).all()
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


def test_availability_prob_near_zero_trains_finite(data):
    """End to end: a short availability run at prob≈0 must keep params and
    losses finite (the degenerate rounds ride the uniform re-draw)."""
    from repro.configs.base import RuntimeModelConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    task = get_paper_task("femnist")
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    rt = RuntimeModel(task.model_size_mb, RuntimeModelConfig(), 4)
    fed = FedConfig(total_clients=12, clients_per_round=4, rounds=3, k0=2,
                    eta0=0.3, batch_size=4, loss_window=3,
                    sampler="availability", availability=1e-12)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    h = tr.run(3)
    assert np.isfinite(h.train_loss).all()
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(tr.params))


def test_availability_zero_data_online_clients_fall_back_uniform():
    """Shortfall weight normalisation must not divide by zero when every
    online client owns an empty dataset."""
    class D:
        num_clients = 6
        client_y = [np.zeros(0)] * 3 + [np.zeros(5)] * 3

    s = AvailabilitySampler(prob=0.5)
    rng = np.random.default_rng(2)
    saw_shortfall = False
    for r in range(40):
        ids, w = s.round(rng, D(), 4, round_idx=r + 1)
        assert np.isfinite(w).all()
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
        if (w == 0).any():
            saw_shortfall = True
    assert saw_shortfall
    # the full-cohort branch (len(online) >= n) rides client_weights, whose
    # zero-total guard must also hold for an all-empty cohort
    w = pipeline.client_weights(D(), [0, 1, 2])
    assert np.isfinite(w).all()
    np.testing.assert_allclose(w, 1.0 / 3.0, rtol=1e-6)


def test_availability_rejects_weight_ignoring_aggregator(data):
    """Shortfall padding encodes participation in the weights; a robust
    aggregator would treat padded offline clients as full participants —
    the trainer must refuse at construction (not just spec validation)."""
    from repro.configs.base import RuntimeModelConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    task = get_paper_task("femnist")
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    rt = RuntimeModel(task.model_size_mb, RuntimeModelConfig(), 4)
    fed = FedConfig(total_clients=12, clients_per_round=4, rounds=2, k0=2,
                    eta0=0.3, batch_size=4, loss_window=3,
                    sampler="availability", aggregator="median")
    with pytest.raises(ValueError, match="weight-respecting"):
        FedAvgTrainer(loss_fn, params, data, fed, rt)


def test_get_sampler_registry_and_fed_config():
    fed = FedConfig(sampler="fixed_cohort", cohort=(2, 4), clients_per_round=2)
    s = make_sampler(fed)
    assert isinstance(s, FixedCohortSampler) and s.cohort == (2, 4)
    fed = FedConfig(sampler="availability", availability=0.5)
    s = make_sampler(fed)
    assert isinstance(s, AvailabilitySampler) and s.prob == 0.5
    assert isinstance(get_sampler(UniformSampler()), UniformSampler)
    with pytest.raises(KeyError, match="Did you mean"):
        make_sampler(FedConfig(sampler="uniformm"))


# ---------------------------------------------------------------------------
# per-client error feedback (fixed cohorts) vs server-aggregate EF
# ---------------------------------------------------------------------------

def _run_engine(transport, params, loss_fn, buckets):
    eng = RoundEngine(loss_fn, transport=transport)
    p = params
    ss = eng.init_server_state(params)
    eng.init_transport_state(params)
    for bb, w, etas, act in buckets:
        p, firsts, _, ss = eng.run_bucket(p, bb, w, etas, act, ss)
    return p, np.asarray(firsts), eng.transport_state


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_per_client_ef_recursion_exact_single_client(codec):
    """With one client at weight 1 the per-client residual recursion IS the
    server-aggregate recursion. Evaluated un-jitted (no XLA fma fusion of
    the aggregate path's weighted-truth einsum), the two ``aggregate``
    formulations are bitwise identical across iterations."""
    rng = np.random.default_rng(5)
    params = {"w": jnp.asarray(rng.normal(size=(40,)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    w = jnp.ones((1,), jnp.float32)

    def mk():
        return (Int8Transport(levels=1, error_feedback=True) if codec == "int8"
                else TopKTransport(frac=0.3, error_feedback=True))

    t_agg, t_pc = mk(), mk().with_ef_slots(1)
    p_agg = p_pc = params
    s_agg, s_pc = t_agg.init_state(params), t_pc.init_state(params)
    for _ in range(4):
        stack = jax.tree.map(
            lambda p: p[None]
            + jnp.asarray(rng.normal(size=(1,) + p.shape)
                          .astype(np.float32)), params)
        p_agg, s_agg = t_agg.aggregate(None, p_agg, stack, w, s_agg)
        p_pc, s_pc = t_pc.aggregate(None, p_pc, stack, w, s_pc)
        for a, b in zip(jax.tree.leaves(p_agg), jax.tree.leaves(p_pc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(s_agg), jax.tree.leaves(s_pc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_per_client_ef_engine_single_client_parity(codec):
    """Through the jitted engine: round 1 (zero residuals) is bitwise; the
    full multi-round run agrees to the quantization-discontinuity regime of
    DESIGN.md §8.5 (XLA fuses the aggregate path's einsum-minus-hat into an
    fma, and a one-ulp residual difference can flip an int8/top-k code)."""
    task = get_paper_task("femnist")
    params = small.init_task_model(jax.random.PRNGKey(1), task)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    rng = np.random.default_rng(5)

    def buckets(n, B=2):
        out = []
        r = np.random.default_rng(5)
        for _ in range(n):
            k, b = 2, 3
            out.append((
                {"x": jnp.asarray(r.normal(size=(B, 1, k, b, 784))
                                  .astype(np.float32)),
                 "y": jnp.asarray(r.integers(0, 62, size=(B, 1, k, b))
                                  .astype(np.int32))},
                jnp.ones((B, 1), jnp.float32),
                np.full(B, 0.2, np.float32), np.ones(B, bool)))
        return out

    def mk():
        return (Int8Transport(levels=1, error_feedback=True) if codec == "int8"
                else TopKTransport(frac=0.3, error_feedback=True))

    # one single-round bucket, zero starting residual: bitwise equal
    p_agg, f_agg, _ = _run_engine(mk(), params, loss_fn, buckets(1, B=1))
    p_pc, f_pc, _ = _run_engine(mk().with_ef_slots(1), params, loss_fn,
                                buckets(1, B=1))
    np.testing.assert_array_equal(f_agg, f_pc)
    for a, b in zip(jax.tree.leaves(p_agg), jax.tree.leaves(p_pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # multi-round: training-sanity closeness only
    p_agg, f_agg, _ = _run_engine(mk(), params, loss_fn, buckets(3))
    p_pc, f_pc, _ = _run_engine(mk().with_ef_slots(1), params, loss_fn,
                                buckets(3))
    np.testing.assert_allclose(f_agg, f_pc, rtol=1e-2, atol=5e-3)
    for a, b in zip(jax.tree.leaves(p_agg), jax.tree.leaves(p_pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-3)


def test_ef_slots_state_shape_and_signature():
    t = Int8Transport(levels=1, error_feedback=True)
    t4 = t.with_ef_slots(4)
    params = {"w": jnp.zeros((5, 3))}
    assert jax.tree.leaves(t.init_state(params))[0].shape == (5, 3)
    assert jax.tree.leaves(t4.init_state(params))[0].shape == (4, 5, 3)
    assert t.signature() != t4.signature()     # distinct compile-cache keys
    # no feedback state => no slots
    t2 = Int8Transport(levels=2, error_feedback=False)
    assert t2.with_ef_slots(4) is t2


def test_fixed_cohort_trainer_switches_to_per_client_ef(data):
    from repro.configs.base import RuntimeModelConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    task = get_paper_task("femnist")
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    rt = RuntimeModel(task.model_size_mb, RuntimeModelConfig(), 4)
    fed = FedConfig(total_clients=12, clients_per_round=4, rounds=3, k0=2,
                    eta0=0.3, batch_size=4, loss_window=3, transport="int8",
                    sampler="fixed_cohort")
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    assert tr.engine.transport.ef_slots == 4
    h = tr.run(3)
    assert np.isfinite(h.train_loss).all()
    lead = jax.tree.leaves(tr.engine.transport_state)[0].shape[0]
    assert lead == 4
    # uniform sampling keeps the aggregate residual
    tr2 = FedAvgTrainer(loss_fn, params, data,
                        FedConfig(total_clients=12, clients_per_round=4,
                                  rounds=3, k0=2, eta0=0.3, batch_size=4,
                                  loss_window=3, transport="int8"), rt)
    assert tr2.engine.transport.ef_slots is None


# ---------------------------------------------------------------------------
# population-scale sampling (DESIGN.md §11): O(cohort) draws over 10^6 ids
# ---------------------------------------------------------------------------

def _million(data):
    from repro.data import PopulationView
    return PopulationView(data, 1_000_000)


def test_availability_sparse_path_at_million_ids(data):
    """Above DENSE_MAX the draw must be O(cohort): 10^6 virtual clients,
    many rounds, well under a second — the historical dense Bernoulli
    (one rng.random(num_clients) per round) would be ~100x slower and is
    the regression this test pins."""
    import time
    view = _million(data)
    s = AvailabilitySampler(prob=0.5)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in range(100):
        ids, w = s.round(rng, view, 32, round_idx=r + 1)
        assert ids.shape == (32,) and len(set(ids.tolist())) == 32
        assert ((0 <= ids) & (ids < 1_000_000)).all()
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    assert time.time() - t0 < 2.0, "sparse availability draw is not O(cohort)"
    # deterministic in the rng stream
    a = AvailabilitySampler(prob=0.5).round(
        np.random.default_rng(3), view, 16)[0]
    b = AvailabilitySampler(prob=0.5).round(
        np.random.default_rng(3), view, 16)[0]
    np.testing.assert_array_equal(a, b)


def test_availability_dense_stream_unchanged_below_threshold(data):
    """At or below DENSE_MAX the historical dense Bernoulli stream is
    bitwise pinned (existing runs depend on it)."""
    s = AvailabilitySampler(prob=0.8)
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    ids, _ = s.round(r1, data, 5)
    online = np.flatnonzero(r2.random(data.num_clients) < 0.8)
    expect = r2.choice(online, size=5, replace=False)
    np.testing.assert_array_equal(ids, expect)


def test_availability_sparse_shortfall_pads_zero_weight(data):
    """Pathologically low prob over a huge population: the accepted prefix
    falls short, offline ids pad the cohort at weight 0 (same policy as
    the dense branch)."""
    view = _million(data)
    s = AvailabilitySampler(prob=1e-7)
    ids, w = s.round(np.random.default_rng(0), view, 8)
    assert ids.shape == (8,) and len(set(ids.tolist())) == 8
    assert w.shape == (8,)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)


def test_population_sampler_diurnal_availability():
    from repro.core.engine.sampling import PopulationSampler, splitmix64
    s = PopulationSampler(population=1_000_000, peak=0.9, base=0.05,
                          day_rounds=24)
    ids = np.arange(0, 1_000_000, 9973)
    for r in (1, 7, 13):
        p = s.availability(ids, r)
        assert (p >= 0.05 - 1e-9).all() and (p <= 0.9 + 1e-9).all()
        np.testing.assert_allclose(p, s.availability(ids, r))  # pure fn
    # a single client's availability swings over the day (cosine curve)
    day = np.array([s.availability(np.array([42]), r)[0] for r in range(24)])
    assert day.max() - day.min() > 0.3
    # ... and is periodic with day_rounds
    np.testing.assert_allclose(day[0], s.availability(np.array([42]), 24)[0])
    # hash is stateless: no per-client array anywhere in the sampler
    assert splitmix64(np.array([7])).dtype == np.uint64


def test_population_sampler_o1_state_draws(data):
    import time
    from repro.core.engine.sampling import PopulationSampler
    view = _million(data)
    s = PopulationSampler(population=1_000_000, peak=0.9, base=0.05,
                          day_rounds=24)
    t0 = time.time()
    seen = []
    for r in range(50):
        ids, w = s.round(np.random.default_rng(r), view, 32, round_idx=r + 1)
        assert ids.shape == (32,) and len(set(ids.tolist())) == 32
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
        seen.append(set(ids.tolist()))
    assert time.time() - t0 < 2.0, "population draw is not O(cohort)"
    # deterministic given (rng, round); round-dependent through the curve
    a = s.round(np.random.default_rng(1), view, 32, round_idx=5)[0]
    b = s.round(np.random.default_rng(1), view, 32, round_idx=5)[0]
    c = s.round(np.random.default_rng(1), view, 32, round_idx=17)[0]
    np.testing.assert_array_equal(a, b)
    assert set(a.tolist()) != set(c.tolist())


def test_population_view_is_lazy_modular(data):
    from repro.data import PopulationView
    view = _million(data)
    assert view.num_clients == 1_000_000
    base = data.num_clients
    np.testing.assert_array_equal(view.client_y[base + 3], data.client_y[3])
    np.testing.assert_array_equal(view.client_x[999_999],
                                  data.client_x[999_999 % base])
    with pytest.raises(IndexError):
        view.client_y[1_000_000]
    with pytest.raises(NotImplementedError):
        view.weights
    # unknown attributes delegate to the base dataset
    assert view.num_classes == data.num_classes
