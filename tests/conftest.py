import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before any jax import; never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def np_rng():
    return np.random.default_rng(0)


class FakeMesh:
    """Duck-typed mesh for sharding-rule unit tests (no real devices)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    def __contains__(self, name):
        return name in self.shape


@pytest.fixture
def mesh16x16():
    return FakeMesh({"data": 16, "model": 16})
