"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fedavg_reduce as fr
from repro.kernels import flash_attention as fa
from repro.kernels import moe_gmm as mg
from repro.kernels import ops, ref, ssd_scan as ss

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# fedavg_reduce
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(4, 512), (16, 4096), (7, 1000), (50, 8193)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_reduce_sweep(n, m, dtype):
    rng = jax.random.PRNGKey(n * m)
    x = jax.random.normal(rng, (n, m), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (n,)))
    out = fr.fedavg_reduce(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.fedavg_reduce_ref(x, w), np.float32),
                               **TOL[dtype])


def test_fedavg_reduce_convex_combination():
    x = jnp.stack([jnp.zeros(300), jnp.ones(300)])
    w = jnp.array([0.25, 0.75])
    np.testing.assert_allclose(np.asarray(fr.fedavg_reduce(x, w, interpret=True)),
                               0.75, rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 128),      # MHA
    (2, 4, 2, 256, 64),       # GQA + padded head_dim
    (1, 8, 1, 384, 128),      # MQA-ish, odd-length grid
])
@pytest.mark.parametrize("variant", ["causal", "window", "softcap", "full"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, hd, variant, dtype):
    rng = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(rng[0], (B, H, S, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(rng[1], (B, KV, S, hd)) * 0.3).astype(dtype)
    v = jax.random.normal(rng[2], (B, KV, S, hd)).astype(dtype)
    kw = {"causal": dict(causal=True),
          "window": dict(causal=True, window=64),
          "softcap": dict(causal=True, softcap=20.0),
          "full": dict(causal=False)}[variant]
    out = fa.flash_attention(q, k, v, interpret=True, **kw)
    want = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_model_layout_and_grad():
    rng = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(rng[0], (2, 256, 4, 64)) * 0.3
    k = jax.random.normal(rng[1], (2, 256, 2, 64)) * 0.3
    v = jax.random.normal(rng[2], (2, 256, 2, 64))

    def f_kernel(q):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True) ** 2)

    def f_ref(q):
        qt, kt, vt = (jnp.moveaxis(t, 1, 2) for t in (q, k, v))
        o = ref.flash_attention_ref(qt, kt, vt, causal=True)
        return jnp.sum(jnp.moveaxis(o, 2, 1) ** 2)

    np.testing.assert_allclose(float(f_kernel(q)), float(f_ref(q)), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(jax.grad(f_kernel)(q)),
                               np.asarray(jax.grad(f_ref)(q)),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 96, 3, 64, 32, 32),
    (1, 256, 1, 64, 128, 64),   # mamba2-780m-like ratios
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N)) * 0.5
    c = jax.random.normal(ks[4], (B, S, N)) * 0.5
    d = jnp.linspace(0.5, 1.5, H)
    y, st = ops.ssd_scan(x, dt, A, b, c, d, chunk=chunk)
    yr, sr = ref.ssd_scan_ref(x, dt, A, b, c, d, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=5e-4, atol=5e-4)


def test_ssd_scan_state_equals_stepwise_recurrence():
    """Chunked SSD must equal the naive per-step recurrence."""
    B, S, H, P, N = 1, 40, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, S, N)) * 0.5
    c = jax.random.normal(ks[4], (B, S, N)) * 0.5
    d = jnp.zeros((H,))
    y, _ = ops.ssd_scan(x, dt, A, b, c, d, chunk=8)

    st = np.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A))     # (B,H)
        st = st * decay[..., None, None] + np.einsum(
            "bh,bn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(b[:, t]),
            np.asarray(x[:, t]))
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(c[:, t]), st))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), y_naive, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# moe_gmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,d,f", [(4, 128, 256, 512), (8, 100, 512, 384),
                                     (2, 257, 320, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, C, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(E + C), 2)
    x = (jax.random.normal(ks[0], (E, C, d)) * 0.1).astype(dtype)
    w = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(dtype)
    np.testing.assert_allclose(np.asarray(ops.gmm(x, w), np.float32),
                               np.asarray(ref.gmm_ref(x, w), np.float32),
                               **TOL[dtype])


def test_moe_ffn_kernel_matches_oracle():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (4, 64, 128)) * 0.3
    gate = jax.random.normal(ks[1], (4, 128, 256)) * 0.05
    up = jax.random.normal(ks[2], (4, 128, 256)) * 0.05
    down = jax.random.normal(ks[0], (4, 256, 128)) * 0.05
    np.testing.assert_allclose(
        np.asarray(ops.moe_gmm(x, gate, up, down)),
        np.asarray(ref.moe_ffn_ref(x, gate, up, down)),
        rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# top-k scatter (Mosaic one-hot matmul, DESIGN.md §10)
# ---------------------------------------------------------------------------

from repro.kernels import delta_codec as dc          # noqa: E402
from repro.launch.mesh import make_host_mesh         # noqa: E402


def _topk_payload(seed, n, k, m):
    """Random (N, S) payload; indices drawn WITH replacement so duplicate
    coordinates (several clients keeping the same weight) are the common
    case, not the edge case."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(ks[0], (n, k))
    idx = jax.random.randint(ks[1], (n, k), 0, max(m, 1), dtype=jnp.int32)
    w = jax.nn.softmax(jax.random.normal(ks[2], (n,)))
    return vals, idx, w


@pytest.mark.parametrize("n,k,m", [(3, 5, 17), (8, 64, 1000), (1, 1, 1),
                                   (2, 7, 1), (5, 130, 4099)])
def test_topk_scatter_reduce_mosaic_sweep(n, k, m):
    vals, idx, w = _topk_payload(n * 1000 + k, n, k, m)
    want = dc.topk_scatter_reduce(vals, idx, w, m)
    got = dc.topk_scatter_reduce_mosaic(vals, idx, w, m, interpret=True)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_topk_scatter_reduce_mosaic_duplicates_accumulate():
    """Colliding coordinates must sum, exactly as the XLA scatter-add."""
    vals = jnp.array([[1.0, 2.0, 4.0], [8.0, 16.0, 32.0]])
    idx = jnp.array([[0, 0, 3], [3, 1, 0]], jnp.int32)
    w = jnp.array([1.0, 0.5])
    got = dc.topk_scatter_reduce_mosaic(vals, idx, w, 5, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.array([1 + 2 + 16, 8, 0, 4 + 4, 0], np.float32))


def test_topk_scatter_reduce_mosaic_empty_payload():
    """k == 0 (codec kept nothing) must yield an exact zero reduction."""
    vals = jnp.zeros((2, 0))
    idx = jnp.zeros((2, 0), jnp.int32)
    w = jnp.array([0.5, 0.5])
    got = dc.topk_scatter_reduce_mosaic(vals, idx, w, 37, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(37, np.float32))


@pytest.mark.parametrize("s,m", [(6, 40), (1, 1), (130, 4099)])
def test_topk_scatter_apply_mosaic_matches_xla_bitwise(s, m):
    """Unique indices: the one-hot matmul adds exactly one f32 term per
    output slot, so reconstruction is bit-identical to the XLA scatter."""
    ks = jax.random.split(jax.random.PRNGKey(s * m), 3)
    refv = jax.random.normal(ks[0], (m,))
    vals = jax.random.normal(ks[1], (s,))
    idx = jax.random.permutation(ks[2], m)[:s].astype(jnp.int32)
    got = dc.topk_scatter_apply_mosaic(refv, vals, idx, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(dc.topk_scatter_apply(refv, vals, idx)))


def test_topk_scatter_apply_mosaic_duplicates_and_empty():
    refv = jnp.array([10.0, 20.0, 30.0])
    vals = jnp.array([1.0, 2.0, 4.0])
    idx = jnp.array([2, 2, 0], jnp.int32)
    got = dc.topk_scatter_apply_mosaic(refv, vals, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(got), [14.0, 20.0, 33.0],
                               rtol=1e-6)
    # empty payload: the reference passes through untouched
    empty = dc.topk_scatter_apply_mosaic(
        refv, jnp.zeros((0,)), jnp.zeros((0,), jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(empty), np.asarray(refv))


def test_topk_scatter_sharded_matches_unsharded():
    mesh = make_host_mesh()
    vals, idx, w = _topk_payload(11, 4, 16, 513)
    want = dc.topk_scatter_reduce_mosaic(vals, idx, w, 513, interpret=True)
    got = dc.topk_scatter_reduce_sharded(vals, idx, w, 513, mesh=mesh,
                                         client_axes=("data",),
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_mosaic_scatter_dispatch_gate():
    """ops.topk_delta_reduce picks Mosaic for small dense work volumes and
    the XLA oracle beyond the interpret-mode ceiling — both must agree."""
    assert ops.mosaic_scatter_ok(8, 100)
    if ops.INTERPRET:
        assert not ops.mosaic_scatter_ok(1 << 12, 1 << 12)
    vals, idx, w = _topk_payload(0, 4, 16, 333)
    out = ops.topk_delta_reduce(vals, idx, w, 333)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(dc.topk_scatter_reduce(vals, idx, w, 333)),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# hierarchical two-tier reduce (grouped psum, DESIGN.md §11)
# ---------------------------------------------------------------------------

def _pod_data_mesh():
    """Two client axes on one host device — exercises the grouped-axes
    collective lowering without needing multiple devices."""
    return jax.make_mesh((1, 1), ("pod", "data"))


def test_psum_tiers_rejects_non_partition():
    with pytest.raises(ValueError, match="partition"):
        fr.psum_tiers(jnp.zeros(4), ("pod", "data"), (("data",),))
    with pytest.raises(ValueError, match="partition"):
        fr.psum_tiers(jnp.zeros(4), ("pod", "data"),
                      (("data",), ("pod", "data")))


def test_fedavg_reduce_sharded_grouped_matches_flat():
    mesh = _pod_data_mesh()
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (8,)))
    flat = fr.fedavg_reduce_sharded(x, w, mesh=mesh,
                                    client_axes=("pod", "data"),
                                    interpret=True)
    grouped = fr.fedavg_reduce_sharded(x, w, mesh=mesh,
                                       client_axes=("pod", "data"),
                                       interpret=True,
                                       reduce_tiers=(("data",), ("pod",)))
    assert np.abs(np.asarray(grouped) - np.asarray(flat)).max() <= 1e-6


def test_int8_delta_reduce_sharded_grouped_matches_flat():
    q = jax.random.randint(jax.random.PRNGKey(2), (4, 2048), -127, 128,
                           dtype=jnp.int8)
    w_eff = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3), (4,)))
    mesh = _pod_data_mesh()
    kw = dict(mesh=mesh, client_axes=("pod", "data"), interpret=True)
    flat = dc.int8_decompress_reduce_sharded(q, w_eff, **kw)
    grouped = dc.int8_decompress_reduce_sharded(
        q, w_eff, reduce_tiers=(("data",), ("pod",)), **kw)
    assert np.abs(np.asarray(grouped) - np.asarray(flat)).max() <= 1e-6


def test_topk_scatter_sharded_grouped_matches_flat():
    vals, idx, w = _topk_payload(23, 4, 16, 513)
    mesh = _pod_data_mesh()
    kw = dict(mesh=mesh, client_axes=("pod", "data"), interpret=True)
    flat = dc.topk_scatter_reduce_sharded(vals, idx, w, 513, **kw)
    grouped = dc.topk_scatter_reduce_sharded(
        vals, idx, w, 513, reduce_tiers=(("data",), ("pod",)), **kw)
    assert np.abs(np.asarray(grouped) - np.asarray(flat)).max() <= 1e-6
