"""Unit tests for the paper's K_r / eta_r decay schedules (Table 3)."""
import math

import pytest

from repro.configs.base import FedConfig
from repro.core import DecayController, quantize_k, schedule_preview


def make(k_schedule="fixed", eta_schedule="fixed", **kw):
    return FedConfig(k0=80, eta0=0.3, k_schedule=k_schedule,
                     eta_schedule=eta_schedule, loss_window=5,
                     plateau_patience=3, **kw)


def test_fixed_and_dsgd():
    assert schedule_preview(make("fixed"), 5) == [80] * 5
    assert schedule_preview(make("dsgd"), 5) == [1] * 5


def test_rounds_schedule_matches_eq10():
    fed = make("rounds")
    ks = schedule_preview(fed, 1000)
    for r in (1, 2, 10, 100, 1000):
        assert ks[r - 1] == math.ceil(80 / r ** (1 / 3))
    assert ks[0] == 80
    # monotone non-increasing
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_eta_rounds_matches_eq12():
    ctrl = DecayController(make(eta_schedule="rounds"))
    for r in (1, 4, 100):
        assert ctrl.eta_for_round(r) == pytest.approx(0.3 / math.sqrt(r))


def test_error_schedule_uses_rolling_window():
    ctrl = DecayController(make("error"))
    # cold: K stays at K0 until the window (5) fills — paper §3.5
    for r in range(1, 5):
        assert ctrl.k_for_round(r) == 80
        ctrl.observe_round_losses(1.0)
    assert ctrl.k_for_round(5) == 80          # ratio 1.0
    # loss drops to 1/8 => cbrt(1/8) = 1/2 => K = 40
    for _ in range(20):
        ctrl.observe_round_losses(0.125)
    assert ctrl.k_for_round(6) == 40


def test_error_eta_schedule():
    ctrl = DecayController(make(eta_schedule="error"))
    for _ in range(10):
        ctrl.observe_round_losses(0.25)
    ctrl._f0 = 1.0
    assert ctrl.eta_for_round(7) == pytest.approx(0.3 * 0.5)


def test_step_schedule_decays_on_plateau():
    ctrl = DecayController(make("step"))
    assert ctrl.k_for_round(1) == 80
    ctrl.observe_validation(0.5)
    for _ in range(5):
        ctrl.observe_validation(0.5)          # no improvement
    assert ctrl.plateau.plateaued
    assert ctrl.k_for_round(10) == 8          # K0/10


def test_cosine_beyond_paper():
    fed = make("cosine", rounds=100)
    ks = schedule_preview(fed, 100)
    assert ks[0] == 80 and ks[-1] <= 2
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_quantize_k_bounds_distinct_values():
    fed = FedConfig(k0=80, k_schedule="rounds", k_quantize=True)
    ks = schedule_preview(fed, 5000)
    raw = schedule_preview(FedConfig(k0=80, k_schedule="rounds"), 5000)
    assert len(set(ks)) < len(set(raw))
    assert len(set(ks)) <= 16                  # geometric grid is small
    # quantization never increases K above the unquantized K0
    assert max(ks) <= 80 and min(ks) >= 1


def test_invalid_schedule_raises():
    with pytest.raises(ValueError):
        DecayController(FedConfig(k_schedule="bogus"))
    with pytest.raises(ValueError):
        DecayController(FedConfig(eta_schedule="bogus"))
