"""Unit tests for the paper's K_r / eta_r decay schedules (Table 3)."""
import math

import pytest

from repro.configs.base import FedConfig
from repro.core import DecayController, quantize_k, schedule_preview


def make(k_schedule="fixed", eta_schedule="fixed", **kw):
    return FedConfig(k0=80, eta0=0.3, k_schedule=k_schedule,
                     eta_schedule=eta_schedule, loss_window=5,
                     plateau_patience=3, **kw)


def test_fixed_and_dsgd():
    assert schedule_preview(make("fixed"), 5) == [80] * 5
    assert schedule_preview(make("dsgd"), 5) == [1] * 5


def test_rounds_schedule_matches_eq10():
    fed = make("rounds")
    ks = schedule_preview(fed, 1000)
    for r in (1, 2, 10, 100, 1000):
        assert ks[r - 1] == math.ceil(80 / r ** (1 / 3))
    assert ks[0] == 80
    # monotone non-increasing
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_eta_rounds_matches_eq12():
    ctrl = DecayController(make(eta_schedule="rounds"))
    for r in (1, 4, 100):
        assert ctrl.eta_for_round(r) == pytest.approx(0.3 / math.sqrt(r))


def test_error_schedule_uses_rolling_window():
    ctrl = DecayController(make("error"))
    # cold: K stays at K0 until the window (5) fills — paper §3.5
    for r in range(1, 5):
        assert ctrl.k_for_round(r) == 80
        ctrl.observe_round_losses(1.0)
    assert ctrl.k_for_round(5) == 80          # ratio 1.0
    # loss drops to 1/8 => cbrt(1/8) = 1/2 => K = 40
    for _ in range(20):
        ctrl.observe_round_losses(0.125)
    assert ctrl.k_for_round(6) == 40


def test_error_eta_schedule():
    ctrl = DecayController(make(eta_schedule="error"))
    for _ in range(10):
        ctrl.observe_round_losses(0.25)
    ctrl._f0 = 1.0
    assert ctrl.eta_for_round(7) == pytest.approx(0.3 * 0.5)


def test_step_schedule_decays_on_plateau():
    ctrl = DecayController(make("step"))
    assert ctrl.k_for_round(1) == 80
    ctrl.observe_validation(0.5)
    for _ in range(5):
        ctrl.observe_validation(0.5)          # no improvement
    assert ctrl.plateau.plateaued
    assert ctrl.k_for_round(10) == 8          # K0/10


def test_cosine_beyond_paper():
    fed = make("cosine", rounds=100)
    ks = schedule_preview(fed, 100)
    assert ks[0] == 80 and ks[-1] <= 2
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_quantize_k_bounds_distinct_values():
    fed = FedConfig(k0=80, k_schedule="rounds", k_quantize=True)
    ks = schedule_preview(fed, 5000)
    raw = schedule_preview(FedConfig(k0=80, k_schedule="rounds"), 5000)
    assert len(set(ks)) < len(set(raw))
    assert len(set(ks)) <= 16                  # geometric grid is small
    # quantization never increases K above the unquantized K0
    assert max(ks) <= 80 and min(ks) >= 1


def test_invalid_schedule_raises():
    with pytest.raises(ValueError):
        DecayController(FedConfig(k_schedule="bogus"))
    with pytest.raises(ValueError):
        DecayController(FedConfig(eta_schedule="bogus"))


# ---------------------------------------------------------------------------
# quantize_k grid edge cases
# ---------------------------------------------------------------------------

def test_quantize_k_edges():
    # k at or above k0 snaps to k0; k at or below 1 snaps to 1
    assert quantize_k(80, 80) == 80
    assert quantize_k(200, 80) == 80
    assert quantize_k(1, 80) == 1
    assert quantize_k(0, 80) == 1
    assert quantize_k(-3, 80) == 1
    # degenerate grids
    assert quantize_k(1, 1) == 1
    assert quantize_k(2, 2) == 2
    assert quantize_k(1, 2) == 1


def test_quantize_k_grid_size_bounded():
    for k0 in (2, 7, 80, 128):
        grid = {quantize_k(k, k0) for k in range(1, k0 + 1)}
        assert all(1 <= kq <= k0 for kq in grid)
        assert len(grid) <= math.floor(math.log(k0) / math.log(1.35)) + 2


def test_quantize_k_monotone():
    k0 = 80
    qs = [quantize_k(k, k0) for k in range(1, k0 + 1)]
    assert all(a <= b for a, b in zip(qs, qs[1:]))


# ---------------------------------------------------------------------------
# DecayController feedback paths
# ---------------------------------------------------------------------------

def test_error_ratio_clamped_when_loss_rises():
    """F_r/F0 is clamped to [0, 1]: a rising loss never pushes K above K0
    or eta above eta0 (Eq. 13/14 with the paper's clamp)."""
    ctrl = DecayController(make("error", eta_schedule="error"))
    ctrl.observe_round_losses(1.0)                # sets F0
    for _ in range(10):
        ctrl.observe_round_losses(5.0)            # diverging loss
    assert ctrl._error_ratio() == 1.0
    assert ctrl.k_for_round(20) == 80
    assert ctrl.eta_for_round(20) == pytest.approx(0.3)


def test_error_ratio_cold_until_window_full():
    ctrl = DecayController(make("error"))
    ctrl.observe_round_losses(1.0)                # snapshots F0
    for _ in range(3):                            # window is 5
        ctrl.observe_round_losses(0.001)
        assert ctrl._error_ratio() == 1.0         # still warming
    ctrl.observe_round_losses(0.001)              # window full
    assert ctrl._error_ratio() < 1.0
    for _ in range(5):                            # F0 sample rolls out
        ctrl.observe_round_losses(0.001)
    assert ctrl._error_ratio() < 0.01


def test_f0_snapshots_first_round():
    ctrl = DecayController(make("error"))
    ctrl.observe_round_losses(4.0)
    for _ in range(10):
        ctrl.observe_round_losses(0.5)
    assert ctrl._f0 == 4.0
    assert ctrl._error_ratio() == pytest.approx(0.125)


def test_plateau_trigger_requires_patience():
    ctrl = DecayController(make("step"))          # patience=3
    ctrl.observe_validation(0.5)
    ctrl.observe_validation(0.4)                  # improving: resets
    ctrl.observe_validation(0.4)
    ctrl.observe_validation(0.4)
    assert not ctrl.plateau.plateaued
    ctrl.observe_validation(0.4)
    assert ctrl.plateau.plateaued
    assert ctrl.k_for_round(10) == 8              # K0/10
    # eta-step decays by the same factor
    ctrl_eta = DecayController(make(eta_schedule="step"))
    for _ in range(6):
        ctrl_eta.observe_validation(0.9)
    assert ctrl_eta.eta_for_round(10) == pytest.approx(0.3 / 10.0)
