"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=128,
<=4 experts) of each assigned arch runs one forward/train step on CPU with
correct shapes and no NaNs; decode matches the full-sequence forward
(teacher-forcing consistency — this validates the KV cache, the SSM
recurrence vs the chunked SSD, sliding windows and RoPE positions at once).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import registry

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, rng, B=2, S=16):
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.arch_type == "audio":
        batch["audio_embeds"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.num_patch_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = registry.init(rng, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss_fn = registry.loss_fn(cfg, moe_path="dense")
    loss, metrics = loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one SGD step decreases loss on the same batch (sanity of grads)
    grads = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    new = jax.tree.map(lambda w, g: w - 0.1 * g, params, grads)
    loss2, _ = loss_fn(new, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 1e-3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    if cfg.arch_type == "vlm":
        cfg = dataclasses.replace(cfg, num_patch_tokens=0)  # text-only decode
    rng = jax.random.PRNGKey(0)
    params = registry.init(rng, cfg)
    B, S = 2, 8
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    if cfg.arch_type == "audio":
        audio = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        from repro.models import encdec
        full_logits, _ = encdec.forward_encdec(params, cfg, tok, audio)
        cache = registry.init_cache(params, cfg, B, S, audio_embeds=audio)
    else:
        from repro.models import transformer
        full_logits, _ = transformer.forward_lm(params, cfg, tok,
                                                moe_path="dense")
        cache = registry.init_cache(params, cfg, B, S)

    step = registry.decode_fn(cfg, moe_path="dense")
    for pos in range(S):
        logits, cache = step(params, cache, tok[:, pos], jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, pos]),
            rtol=2e-2, atol=2e-2,
        )


def test_param_counts_match_published_sizes():
    """Full configs must land near the published parameter counts."""
    expect = {
        "qwen1.5-0.5b": (0.46e9, 0.65e9),
        "qwen2-7b": (7.0e9, 8.0e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        "gemma2-27b": (26e9, 29e9),
        "mixtral-8x22b": (138e9, 143e9),
        "nemotron-4-340b": (320e9, 350e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
        "llava-next-34b": (32e9, 36e9),
        # zamba2: shared attn block without the per-invocation LoRA adapters
        # of the released model => fewer params than the "7B" name (DESIGN.md)
        "zamba2-7b": (5.5e9, 8.5e9),
        "whisper-tiny": (25e6, 45e6),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.param_count(get_arch(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:,.0f}, {hi:,.0f}]"


def test_moe_active_params():
    cfg = get_arch("mixtral-8x22b")
    total = registry.param_count(cfg)
    active = registry.active_param_count(cfg)
    assert active < total
    assert 35e9 < active < 45e9     # mixtral-8x22b ~39B active


def test_long_context_flags_match_design():
    longs = {a for a in ALL_ARCHS if ARCHS[a].supports_long_context}
    assert longs == {"zamba2-7b", "mamba2-780m", "gemma2-27b", "mixtral-8x22b"}


def test_ring_cache_decode_matches_full_cache():
    """Beyond-paper R1: windowed ring KV cache is EXACT vs the full cache
    (post-RoPE keys + permutation-invariant softmax => slot order is free)."""
    cfg = dataclasses.replace(get_arch("mixtral-8x22b").reduced(),
                              sliding_window=6)
    params = registry.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    cache_f = registry.init_cache(params, cfg, B, S)
    cache_r = registry.init_cache(params, cfg, B, S, ring=True)
    assert jax.tree.leaves(cache_r)[0].shape[2] == 6       # ring length = W
    step_f = registry.decode_fn(cfg, moe_path="dense")
    step_r = registry.decode_fn(cfg, moe_path="dense", ring=True)
    for pos in range(S):
        lf, cache_f = step_f(params, cache_f, tok[:, pos], jnp.int32(pos))
        lr, cache_r = step_r(params, cache_r, tok[:, pos], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=2e-4, atol=2e-4)


def test_int8_kv_cache_decode_close_to_f32():
    """Beyond-paper Q-KV: int8-quantised KV cache preserves top-1 decode
    predictions and keeps logits within quantisation tolerance."""
    cfg = get_arch("qwen2-7b").reduced()
    params = registry.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    cache_f = registry.init_cache(params, cfg, B, S)
    cache_q = registry.init_cache(params, cfg, B, S, quant=True)
    assert jax.tree.leaves(cache_q["stack"]["b0"]["k"])[0].dtype == jnp.int8
    step = registry.decode_fn(cfg, moe_path="dense")
    for pos in range(S):
        lf, cache_f = step(params, cache_f, tok[:, pos], jnp.int32(pos))
        lq, cache_q = step(params, cache_q, tok[:, pos], jnp.int32(pos))
        assert bool((jnp.argmax(lq, -1) == jnp.argmax(lf, -1)).all())
        np.testing.assert_allclose(np.asarray(lq), np.asarray(lf), atol=0.25)
