"""Async buffered aggregation (DESIGN.md §13): sync-parity oracle, bitwise
checkpoint resume, seeded event-clock durations, staleness semantics and the
spec/engine refusal surface."""
import os

import jax
import numpy as np
import pytest

from repro.api import ExperimentSpec, build
from repro.api.experiment import FederatedExperiment
from repro.api.registries import (AGGREGATION_REGISTRY,
                                  STALENESS_WEIGHT_REGISTRY, UnknownNameError)
from repro.configs import get_paper_task
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel
from repro.core.engine import AsyncBufferedEngine, get_staleness_weight
from repro.core.engine.round import ExecutableRegistry
from repro.data import make_paper_task
from repro.models import small

BASE = ("data.kind=paper", "data.task=femnist", "data.clients=16",
        "fed.clients_per_round=8", "fed.rounds=6", "fed.k0=4",
        "fed.batch_size=8", "fed.eval_every=0")


def spec_with(*overrides):
    return ExperimentSpec().with_overrides(*BASE, *overrides)


# ---------------------------------------------------------------------------
# RuntimeModel.draw_client_times (the event clock's duration source)
# ---------------------------------------------------------------------------

def test_draw_client_times_counter_mode_replayable():
    rt = RuntimeModel(40.0, RuntimeModelConfig(beta_seconds=0.3),
                      clients_per_round=8, heterogeneity=0.6, seed=7)
    a = rt.draw_client_times(3, [4, 1, 9], k=10)
    b = rt.draw_client_times(3, [4, 1, 9], k=10)
    assert (a == b).all()                       # pure in (seed, round, id)
    # order-independence: permuting ids permutes the draws
    c = rt.draw_client_times(3, [9, 4, 1], k=10)
    assert c[0] == a[2] and c[1] == a[0] and c[2] == a[1]
    # counter mode consumes no stream state: the model's own rng untouched
    s0 = rt._rng.bit_generator.state["state"]
    rt.draw_client_times(5, [0, 1], k=10)
    assert rt._rng.bit_generator.state["state"] == s0
    # a different seed gives a different trace
    rt2 = RuntimeModel(40.0, RuntimeModelConfig(beta_seconds=0.3),
                       clients_per_round=8, heterogeneity=0.6, seed=8)
    assert not np.allclose(a, rt2.draw_client_times(3, [4, 1, 9], k=10))


def test_draw_client_times_het_zero_is_base_seconds():
    rt = RuntimeModel(40.0, RuntimeModelConfig(download_mbps=20,
                                               upload_mbps=5,
                                               beta_seconds=0.31),
                      clients_per_round=8, heterogeneity=0.0)
    t = rt.draw_client_times(1, np.arange(8), k=50)
    assert (t == pytest.approx(2 + 50 * 0.31 + 8)) if np.isscalar(t) else \
        np.allclose(t, 2 + 50 * 0.31 + 8)
    # het == 0 reconciliation: round_cost wall == every client's duration
    assert rt.round_cost(50).wall_clock_s == pytest.approx(float(t[0]))


def test_round_cost_consumes_stream_mode_draw_bitwise():
    """round_cost's straggler wall is exactly max(draw_client_times) off the
    same rng stream — the historical base * max(mult) draw bit-for-bit."""
    kw = dict(model_size_mbit=40.0, cfg=RuntimeModelConfig(beta_seconds=0.5),
              clients_per_round=12, heterogeneity=0.7, seed=11)
    a, b = RuntimeModel(**kw), RuntimeModel(**kw)
    for k in (8, 4, 2):
        wall = a.round_cost(k).wall_clock_s
        times = b.draw_client_times(None, np.arange(12), k)
        assert wall == float(np.max(times))     # bitwise, not approx


# ---------------------------------------------------------------------------
# sync-parity oracle + sync program identity
# ---------------------------------------------------------------------------

def test_async_sync_parity_oracle():
    """Zero jitter + buffer_size == cohort reproduces the synchronous
    trainer under a decaying-K schedule: same sampler/batch rng stream, same
    per-version K/eta, loss trajectories equal to f32 fold rounding, and
    wall-clock / steps / wire equal exactly."""
    hs = build(spec_with("fed.k_schedule=rounds",
                         "fed.aggregation=sync")).run()
    ha = build(spec_with("fed.k_schedule=rounds",
                         "fed.aggregation=async")).run()
    assert ha.rounds == hs.rounds and ha.k == hs.k and ha.eta == hs.eta
    np.testing.assert_allclose(ha.train_loss, hs.train_loss,
                               rtol=0, atol=5e-6)
    assert ha.wall_clock_s == hs.wall_clock_s
    assert ha.sgd_steps == hs.sgd_steps
    assert ha.downlink_mbit == hs.downlink_mbit
    np.testing.assert_allclose(ha.uplink_mbit, hs.uplink_mbit, rtol=1e-12)
    assert all(s == 0.0 for s in ha.staleness)  # nobody is ever stale


def test_sync_aggregation_keeps_executable_keys_bitwise():
    """aggregation='sync' through the AggregationPolicy registry is the
    FedAvgTrainer construction verbatim: same class, and the AOT registry
    keys it compiles are bit-for-bit the directly-constructed trainer's."""
    from repro.api.sweep import spec_program_key
    spec = spec_with("fed.k_schedule=rounds")
    key = spec_program_key(spec)

    reg_api = ExecutableRegistry()
    exp = build(spec, registry=reg_api)
    assert type(exp.trainer) is FedAvgTrainer
    exp.run(3)

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(spec.data.seed),
                           num_clients=spec.data.clients,
                           samples_per_client=spec.data.samples_per_client)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    from repro.api.experiment import _make_fed_config
    fed = _make_fed_config(spec)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 8)
    reg_direct = ExecutableRegistry()
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt, registry=reg_direct,
                       program_key=key)
    tr.run(3, eval_every=0)
    assert set(reg_api._entries) == set(reg_direct._entries)


# ---------------------------------------------------------------------------
# checkpointing: mid-buffer bitwise resume (in-process + fresh-process)
# ---------------------------------------------------------------------------

ASYNC_HET = ("fed.rounds=8", "fed.aggregation=async", "fed.buffer_size=3",
             "fed.staleness_weight=inv", "fed.k_schedule=rounds",
             "runtime.heterogeneity=0.7")


def _assert_trees_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert (np.asarray(x) == np.asarray(y)).all()


@pytest.mark.parametrize("transport", ["none", "int8"])
def test_mid_buffer_checkpoint_bitwise_resume(tmp_path, transport):
    """Save with a part-filled buffer, in-flight deltas and a non-empty
    event heap; a fresh-process restore (spec rebuilt from the checkpoint)
    continues bitwise: history, params, staleness histogram, byte and
    drop counters."""
    spec = spec_with(*ASYNC_HET, f"transport.name={transport}",
                     "fed.max_staleness=4")
    ref = build(spec)
    href = ref.run()

    a = build(spec)
    a.trainer.run(4)
    assert a.trainer._buf_count != 0 or a.trainer._heap  # mid-simulation
    ck = os.path.join(tmp_path, "ck")
    a.save(ck)

    b = FederatedExperiment.restore(ck)          # fresh build from the spec
    assert type(b.trainer) is AsyncBufferedEngine
    hb = b.trainer.run(8, resume=True)

    assert hb.train_loss == href.train_loss      # bitwise, not approx
    assert hb.wall_clock_s == href.wall_clock_s
    assert hb.staleness == href.staleness
    assert hb.uplink_mbit == href.uplink_mbit
    assert hb.applied_updates == href.applied_updates
    assert hb.dropped_updates == href.dropped_updates
    assert b.trainer.staleness_hist == ref.trainer.staleness_hist
    _assert_trees_bitwise(b.trainer.params, ref.trainer.params)
    _assert_trees_bitwise(b.trainer.transport_state,
                          ref.trainer.transport_state)


def test_checkpoint_restores_event_heap_and_version_vector(tmp_path):
    spec = spec_with(*ASYNC_HET)
    a = build(spec)
    a.trainer.run(3)
    ck = os.path.join(tmp_path, "ck")
    a.save(ck)
    b = FederatedExperiment.restore(ck)
    assert b.trainer._heap == a.trainer._heap
    assert (b.trainer._slot_version == a.trainer._slot_version).all()
    assert (b.trainer._slot_client == a.trainer._slot_client).all()
    assert b.trainer._buf_weight == a.trainer._buf_weight
    assert b.trainer._sim_time == a.trainer._sim_time
    assert b.trainer._np_rng.bit_generator.state == \
        a.trainer._np_rng.bit_generator.state
    _assert_trees_bitwise(b.trainer._inflight, a.trainer._inflight)
    _assert_trees_bitwise(b.trainer._buffer, a.trainer._buffer)


# ---------------------------------------------------------------------------
# staleness semantics
# ---------------------------------------------------------------------------

def test_staleness_weight_builtins():
    assert get_staleness_weight("constant")(3) == 1.0
    assert get_staleness_weight("inv")(3) == pytest.approx(0.25)
    assert get_staleness_weight("poly")(3) == pytest.approx(0.5)
    with pytest.raises(UnknownNameError, match="Did you mean 'inv'"):
        STALENESS_WEIGHT_REGISTRY.get("inf")


def test_max_staleness_drops_are_counted_and_charged():
    spec = spec_with("fed.aggregation=async", "fed.buffer_size=2",
                     "fed.max_staleness=0", "runtime.heterogeneity=1.0",
                     "fed.rounds=4")
    exp = build(spec)
    h = exp.run()
    tr = exp.trainer
    assert tr.dropped_updates > 0                # het 1.0: staleness happens
    assert h.dropped_updates[-1] == tr.dropped_updates
    assert sum(tr.staleness_hist.values()) == \
        tr.applied_updates + tr.dropped_updates
    # dropped arrivals still shipped their bytes
    arrivals = tr.applied_updates + tr.dropped_updates + tr._buf_count
    assert h.uplink_mbit[-1] == pytest.approx(
        arrivals * tr.runtime.uplink_mbit_per_client)


def test_async_history_gains_staleness_columns():
    h = build(spec_with("fed.aggregation=async",
                        "runtime.heterogeneity=0.5")).run()
    assert len(h.staleness) == len(h.rounds)
    assert len(h.applied_updates) == len(h.rounds)
    assert h.applied_updates == sorted(h.applied_updates)  # cumulative
    assert np.isfinite(h.train_loss).all()


# ---------------------------------------------------------------------------
# refusals — spec-time and engine-time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overrides, msg", [
    (("fed.aggregation=async", "fed.aggregator=median"), "robust"),
    (("fed.aggregation=async", "fed.cohort_chunk=4"), "cohort_chunk"),
    (("fed.aggregation=async", "fed.buffer_size=9"), "buffer_size"),
    (("fed.aggregation=async", "fed.buffer_size=0"), "buffer_size"),
    (("fed.aggregation=async", "transport.downlink=int8"), "downlink"),
    (("fed.aggregation=async", "sampler.name=fixed_cohort"), "sampler"),
    (("fed.aggregation=async", "backend.name=mesh",
      "backend.strategy=sequential"), "sequential"),
    (("fed.aggregation=async", "fed.max_staleness=-1"), "max_staleness"),
    (("fed.buffer_size=4",), "async"),           # sync refuses async knobs
    (("fed.max_staleness=2",), "async"),
    (("fed.staleness_weight=inv",), "async"),
])
def test_spec_refusals(overrides, msg):
    with pytest.raises(ValueError, match=msg):
        spec_with(*overrides).validate()


def test_spec_unknown_aggregation_suggests():
    with pytest.raises(ValueError, match="sync"):
        spec_with("fed.aggregation=asink").validate()


def test_engine_refusals_mirror_spec():
    """A hand-built FedConfig that skips spec validation still gets loud
    engine-time refusals."""
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=8, samples_per_client=20)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 4)

    def engine(**kw):
        fed = FedConfig(total_clients=8, clients_per_round=4, rounds=2,
                        k0=2, batch_size=4, aggregation="async", **kw)
        return AsyncBufferedEngine(loss_fn, params, data, fed, rt)

    with pytest.raises(ValueError, match="linear"):
        engine(aggregator="median")
    with pytest.raises(ValueError, match="cohort_chunk"):
        engine(cohort_chunk=2)
    with pytest.raises(ValueError, match="downlink"):
        engine(downlink="int8")
    with pytest.raises(ValueError, match="buffer_size"):
        engine(buffer_size=64)
    with pytest.raises(ValueError, match="ragged"):
        engine(sampler="fixed_cohort")


def test_aggregation_registry_lists_builtins():
    assert set(AGGREGATION_REGISTRY.available()) >= {"sync", "async"}
    assert set(STALENESS_WEIGHT_REGISTRY.available()) >= \
        {"constant", "inv", "poly"}
