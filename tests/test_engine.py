"""Round-engine tests: K-bucketed execution parity with the seed loop,
scheduler planning, pluggable aggregators/server optimizers, prefetch
determinism, and the compile-count bound (DESIGN.md §6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import (DecayController, FedAvgTrainer, RuntimeModel,
                        quantize_k, run_reference_rounds)
from repro.core.engine import aggregators, get_server_optimizer
from repro.core.engine.scheduler import RoundScheduler, is_loss_free
from repro.data import make_paper_task, pipeline
from repro.models import small


@pytest.fixture(scope="module")
def femnist_setup():
    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=20, samples_per_client=40)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    return task, data, loss_fn, params


def trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# parity: bucketed multi-round execution == seed per-round loop, bitwise
# ---------------------------------------------------------------------------

def test_bucketed_parity_with_seed_loop(femnist_setup):
    """Acceptance: fixed-K, >=20 rounds, bitwise-identical params."""
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=24, k0=6,
                    eta0=0.3, batch_size=8, k_schedule="fixed", seed=0)
    ref = run_reference_rounds(loss_fn, params, data, fed, 24)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    h = tr.run(24)
    assert trees_equal(ref.params, tr.params)
    np.testing.assert_allclose(ref.losses, h.train_loss, rtol=1e-6)
    assert ref.ks == h.k
    assert tr.compile_count == 1          # one K -> one executable


def test_parity_padded_tail_bucket(femnist_setup):
    """23 rounds (prime) with bucket_rounds=8 forces a padded tail bucket;
    masked padding rounds must be bitwise transparent."""
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=23, k0=5,
                    eta0=0.3, batch_size=8, k_schedule="fixed",
                    bucket_rounds=8, seed=1)
    sched = RoundScheduler(DecayController(fed), fed, total_rounds=23)
    plan = list(sched.plan())
    assert any(len(b) < b.shape_rounds for b in plan), "no padded bucket"
    ref = run_reference_rounds(loss_fn, params, data, fed, 23)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    tr.run(23)
    assert trees_equal(ref.params, tr.params)


def test_parity_with_prefetch_disabled(femnist_setup):
    task, data, loss_fn, params = femnist_setup
    fed_kw = dict(total_clients=20, clients_per_round=6, rounds=16, k0=4,
                  eta0=0.3, batch_size=8, k_schedule="fixed", seed=2)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr_bg = FedAvgTrainer(loss_fn, params, data,
                          FedConfig(**fed_kw, prefetch=True), rt)
    tr_sync = FedAvgTrainer(loss_fn, params, data,
                            FedConfig(**fed_kw, prefetch=False), rt)
    tr_bg.run(16)
    tr_sync.run(16)
    assert trees_equal(tr_bg.params, tr_sync.params)


def test_stateful_server_parity_across_buckets(femnist_setup):
    """fedadam state must thread through bucket scans identically to the
    per-round reference loop."""
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=20, k0=4,
                    eta0=0.3, batch_size=8, k_schedule="fixed",
                    server_optimizer="fedadam", server_lr=0.01, seed=3)
    ref = run_reference_rounds(loss_fn, params, data, fed, 20)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    tr.run(20)
    assert trees_equal(ref.params, tr.params)


# ---------------------------------------------------------------------------
# compile bound
# ---------------------------------------------------------------------------

def test_compile_count_bounded_by_k_grid(femnist_setup):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=60, k0=10,
                    eta0=0.3, batch_size=8, k_schedule="rounds",
                    k_quantize=True, seed=0)
    grid = len({quantize_k(k, fed.k0) for k in range(1, fed.k0 + 1)})
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt)
    h = tr.run(60)
    assert tr.compile_count <= grid
    assert len(set(h.k)) <= grid


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def plan_of(fed, rounds, eval_every=None):
    sched = RoundScheduler(DecayController(fed), fed, total_rounds=rounds,
                           eval_every=eval_every)
    return sched, list(sched.plan())


def test_scheduler_covers_every_round_once():
    fed = FedConfig(k0=10, k_schedule="rounds", k_quantize=True, rounds=50)
    for eval_every in (None, 5, 7):
        _, plan = plan_of(fed, 50, eval_every)
        seen = [r for b in plan for r in b.rounds]
        assert seen == list(range(1, 51))
        for b in plan:
            assert len(b) <= b.shape_rounds
            ctrl = DecayController(fed)
            assert all(ctrl.k_for_round(r) == b.k for r in b.rounds)


def test_scheduler_cuts_at_eval_boundaries():
    fed = FedConfig(k0=8, k_schedule="fixed", rounds=20)
    _, plan = plan_of(fed, 20, eval_every=5)
    ends = [b.rounds[-1] for b in plan if b.eval_after]
    assert ends == [5, 10, 15, 20]
    for b in plan:
        # a bucket never straddles an eval round
        assert not any(r % 5 == 0 for r in b.rounds[:-1])


def test_scheduler_shape_divides_misaligned_eval_window():
    """bucket_rounds=8 with eval_every=10 must not pad 6 of every 16
    computed rounds: the per-K shape adapts (here 5 divides 10 exactly)."""
    fed = FedConfig(k0=8, k_schedule="fixed", rounds=100, bucket_rounds=8)
    _, plan = plan_of(fed, 100, eval_every=10)
    computed = sum(b.shape_rounds for b in plan)
    assert computed == 100                        # zero padding
    assert all(len(b) == b.shape_rounds == 5 for b in plan)


def test_scheduler_one_shape_per_k():
    fed = FedConfig(k0=10, k_schedule="rounds", k_quantize=True, rounds=200)
    _, plan = plan_of(fed, 200)
    shapes = {}
    for b in plan:
        shapes.setdefault(b.k, set()).add(b.shape_rounds)
    assert all(len(s) == 1 for s in shapes.values())


def test_scheduler_feedback_mode_single_round_default():
    fed = FedConfig(k0=8, k_schedule="error", rounds=10, loss_window=3)
    sched, plan = plan_of(fed, 10)
    assert not sched.loss_free and not is_loss_free(fed)
    assert all(len(b) == 1 for b in plan)


# ---------------------------------------------------------------------------
# aggregators
# ---------------------------------------------------------------------------

def _stack(rng, n=6, shape=(4, 3)):
    return {"w": jnp.asarray(rng.normal(size=(n,) + shape).astype(np.float32))}


def test_median_and_trimmed_mean_reject_outlier():
    rng = np.random.default_rng(0)
    clean = _stack(rng)
    poisoned = {"w": clean["w"].at[0].set(1e6)}     # Byzantine client
    w = jnp.full((6,), 1 / 6, jnp.float32)
    med = aggregators.coordinate_median(poisoned, w)["w"]
    trm = aggregators.trimmed_mean(poisoned, w, trim_fraction=0.2)["w"]
    mean = aggregators.weighted_mean(poisoned, w)["w"]
    assert float(jnp.abs(med).max()) < 10.0
    assert float(jnp.abs(trm).max()) < 10.0
    assert float(jnp.abs(mean).max()) > 1e4       # mean is not robust
    # the default fraction must still trim >=1 client at small N
    dflt = aggregators.trimmed_mean(poisoned, w)["w"]
    assert float(jnp.abs(dflt).max()) < 10.0
    # trimmed with degenerate fraction falls back to median
    deg = aggregators.trimmed_mean(poisoned, w, trim_fraction=0.5)["w"]
    np.testing.assert_allclose(np.asarray(deg), np.asarray(med), rtol=1e-6)


def test_trimmed_mean_matches_mean_on_uniform_weights():
    """With no outliers and zero trim, trimmed mean == uniform mean."""
    rng = np.random.default_rng(1)
    stack = _stack(rng)
    w = jnp.full((6,), 1 / 6, jnp.float32)
    trm = aggregators.trimmed_mean(stack, w, trim_fraction=0.0)["w"]
    ref = jnp.mean(stack["w"], axis=0)
    np.testing.assert_allclose(np.asarray(trm), np.asarray(ref), rtol=1e-5)


def test_unknown_aggregator_raises():
    with pytest.raises(ValueError):
        aggregators.get_aggregator("bogus")


def test_robust_aggregator_trains(femnist_setup):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=8, k0=4,
                    eta0=0.3, batch_size=8, aggregator="median", seed=0)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    h = FedAvgTrainer(loss_fn, params, data, fed, rt).run(8)
    assert np.isfinite(h.train_loss).all()
    assert h.min_train_loss[-1] < h.train_loss[0]


# ---------------------------------------------------------------------------
# server optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("server", ["fedavgm", "fedyogi"])
def test_new_server_optimizers_train(femnist_setup, server):
    task, data, loss_fn, params = femnist_setup
    fed = FedConfig(total_clients=20, clients_per_round=6, rounds=8, k0=4,
                    eta0=0.3, batch_size=8, server_optimizer=server,
                    server_lr=0.1 if server == "fedyogi" else 0.5, seed=0)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
    h = FedAvgTrainer(loss_fn, params, data, fed, rt).run(8)
    assert np.isfinite(h.train_loss).all()


def test_unknown_server_optimizer_raises():
    with pytest.raises(ValueError):
        get_server_optimizer("bogus")


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------

def test_prefetcher_matches_sync_builder(femnist_setup):
    _, data, _, _ = femnist_setup
    reqs = [(3, 4, 4), (2, 2, 4), (1, 6, 2)]      # (n_rounds, k, pad_to)
    bg = pipeline.BatchPrefetcher(data, 5, 8, 123)
    sync = pipeline.SyncBatchBuilder(data, 5, 8, 123)
    try:
        for r in reqs:
            bg.submit(*r)
            sync.submit(*r)
        for _ in reqs:
            a, b = bg.get(), sync.get()
            assert np.array_equal(a.batches["x"], b.batches["x"])
            assert np.array_equal(a.batches["y"], b.batches["y"])
            assert np.array_equal(a.weights, b.weights)
            assert np.array_equal(a.active, b.active)
    finally:
        bg.close()


def test_prefetcher_surfaces_worker_errors(femnist_setup):
    _, data, _, _ = femnist_setup
    bg = pipeline.BatchPrefetcher(data, 5, 8, 0)
    try:
        bg.submit(5, 3, 2)                        # pad_to < n_rounds
        bg.submit(2, 3, None)                     # queued behind the error
        with pytest.raises(ValueError):
            bg.get()
        # the worker survives the error and serves later requests
        ok = bg.get()
        assert ok.n_rounds == 2
    finally:
        bg.close()


def test_bucket_batches_padding_masks():
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=8, samples_per_client=12)
    rng = np.random.default_rng(0)
    bb = pipeline.bucket_batches(rng, data, n_rounds=3, k=2,
                                 clients_per_round=4, batch_size=4, pad_to=5)
    assert bb.batches["x"].shape == (5, 4, 2, 4, 784)
    assert bb.active.tolist() == [True, True, True, False, False]
    np.testing.assert_array_equal(bb.batches["x"][3], bb.batches["x"][2])
    np.testing.assert_array_equal(bb.weights[4], bb.weights[2])
