"""Data pipeline + checkpoint tests."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import make_lm_clients, make_paper_task, pipeline
from repro.data.partition import dirichlet_label_skew


def test_dirichlet_alpha_extremes(np_rng):
    skew = dirichlet_label_skew(np_rng, 50, 10, alpha=0.05)
    iid = dirichlet_label_skew(np_rng, 50, 10, alpha=1000.0)
    np.testing.assert_allclose(skew.sum(1), 1.0, rtol=1e-9)
    # low alpha concentrates mass; high alpha is near-uniform
    assert skew.max(axis=1).mean() > 0.6
    assert abs(iid.max(axis=1).mean() - 0.1) < 0.05


@pytest.mark.parametrize("name", ["sent140", "femnist", "cifar100",
                                  "shakespeare"])
def test_paper_task_generators(name, np_rng):
    data = make_paper_task(name, np_rng, num_clients=12, samples_per_client=20)
    assert data.num_clients == 12
    np.testing.assert_allclose(data.weights.sum(), 1.0, rtol=1e-6)
    assert len(data.val_y) > 0
    x0 = data.client_x[0]
    assert x0.shape[0] == 20
    if name == "shakespeare":
        assert data.client_y[0].shape == x0.shape      # next-token labels
        assert x0.max() < 79


def test_round_batches_shapes(np_rng):
    data = make_paper_task("femnist", np_rng, num_clients=10,
                           samples_per_client=30)
    ids = pipeline.sample_clients(np_rng, data, 4)
    assert len(set(ids)) == 4
    b = pipeline.round_batches(np_rng, data, ids, k=5, batch_size=8)
    assert b["x"].shape == (4, 5, 8, 784)
    assert b["y"].shape == (4, 5, 8)
    w = pipeline.client_weights(data, ids)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


def test_lm_clients(np_rng):
    data = make_lm_clients(np_rng, num_clients=6, vocab=100, seq_len=16)
    assert data.client_x[0].shape == (64, 16)
    assert data.client_x[0].dtype == np.int32


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.configs import get_arch
    from repro.models import registry
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(rng, cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, meta={"round": 42, "k": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored, meta = load_checkpoint(path, like)
    assert meta["round"] == 42 and meta["k"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_val_batches_keeps_tail_remainder(np_rng):
    data = make_paper_task("femnist", np_rng, num_clients=4,
                           samples_per_client=10)
    n = len(data.val_y)
    bs = 100
    assert n % bs != 0, "fixture should exercise the ragged tail"
    batches = pipeline.val_batches(data, bs)
    assert sum(len(b["y"]) for b in batches) == n     # nothing dropped
    assert len(batches[-1]["y"]) == n % bs
    np.testing.assert_array_equal(
        np.concatenate([b["y"] for b in batches]), data.val_y)


def test_eval_fn_weights_ragged_tail_exactly():
    """make_eval_fn must equal the whole-split accuracy/loss, not the
    unweighted mean of per-batch means."""
    from repro.core import make_eval_fn
    from repro.data.synthetic import FederatedData
    rng = np.random.default_rng(0)
    n, bs = 100, 32                                   # batches 32,32,32,4
    vx = rng.normal(size=(n, 8)).astype(np.float32)
    vy = rng.integers(0, 2, size=n).astype(np.int32)
    data = FederatedData([vx[:1]], [vy[:1]], vx, vy, 2)

    def loss_fn(params, batch):
        import jax.numpy as jnp
        logits = batch["x"] @ params["w"]
        lab = batch["y"]
        lp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(lp, lab[:, None], 1))
        acc = jnp.mean((jnp.argmax(logits, -1) == lab).astype(jnp.float32))
        return loss, {"acc": acc}

    params = {"w": np.asarray(rng.normal(size=(8, 2)), np.float32)}
    got = make_eval_fn(loss_fn, data, batch_size=bs)(params)
    logits = vx @ params["w"]
    acc_exact = float(np.mean(np.argmax(logits, -1) == vy))
    assert got["acc"] == pytest.approx(acc_exact, abs=1e-6)
    assert got["error"] == pytest.approx(1.0 - acc_exact, abs=1e-6)


def test_history_checkpoint_roundtrip(tmp_path):
    """History -> checkpoint meta -> restore preserves every series."""
    from repro.core import History
    h = History()
    for r in range(1, 6):
        h.rounds.append(r)
        h.k.append(8 - r)
        h.eta.append(0.3 / r)
        h.wall_clock_s.append(10.0 * r)
        h.sgd_steps.append(48 * r)
        h.train_loss.append(1.0 / r)
        h.min_train_loss.append(1.0 / r)
    h.val_rounds.append(5)
    h.val_error.append(0.25)
    h.max_val_acc.append(0.75)
    params = {"w": np.ones((3, 2), np.float32)}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, meta={"round": 5, "history": h.as_dict()})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        params)
    _, meta = load_checkpoint(path, like)
    restored = History.from_dict(meta["history"])
    assert restored.as_dict() == h.as_dict()
    assert restored.k == [7, 6, 5, 4, 3]
    # unknown keys in old checkpoints are ignored, missing ones default
    partial = History.from_dict({"rounds": [1], "bogus": [9]})
    assert partial.rounds == [1] and partial.k == []


@pytest.mark.parametrize("transport", ["none", "int8", "topk"])
def test_trainer_mid_schedule_checkpoint_bitwise_continuation(tmp_path,
                                                              transport):
    """save_state mid-schedule + restore_state + run(resume=True) is
    bitwise identical to the uninterrupted run — params, history (losses,
    wall-clock, bytes-on-wire) AND the transport's error-feedback residual
    all survive the round-trip (DESIGN.md §8 state-ownership contract)."""
    from repro.configs import get_paper_task
    from repro.configs.base import FedConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    from repro.models import small

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=16, samples_per_client=30)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)

    def mk():
        # rounds K-decay: the resumed scheduler must re-plan buckets with
        # absolute round indices for K_r to line up
        fed = FedConfig(total_clients=16, clients_per_round=6, rounds=10,
                        k0=6, eta0=0.3, batch_size=8, k_schedule="rounds",
                        k_quantize=True, seed=0, transport=transport,
                        topk_frac=0.2)
        rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
        return FedAvgTrainer(loss_fn, params, data, fed, rt)

    straight = mk()
    straight.run(10)

    first = mk()
    first.run(6)
    path = os.path.join(tmp_path, "mid")
    first.save_state(path)

    resumed = mk()
    resumed.restore_state(path)
    resumed.run(10, resume=True)

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert straight.history.as_dict() == resumed.history.as_dict()
    for a, b in zip(jax.tree.leaves(straight.engine.transport_state),
                    jax.tree.leaves(resumed.engine.transport_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resume past the end is a no-op
    before = [np.asarray(l).copy() for l in jax.tree.leaves(resumed.params)]
    resumed.run(10, resume=True)
    for a, b in zip(before, jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_resume_continues_wire_byte_counters_bitwise(tmp_path):
    """Mid-schedule resume must CONTINUE the cumulative
    ``History.uplink_mbit``/``downlink_mbit`` byte counters — not re-charge
    rounds already paid for, not reset to zero — and restore the downlink
    broadcast state (``params_ref`` + both EF residuals) bitwise
    (DESIGN.md §8.6 acceptance contract)."""
    from repro.configs import get_paper_task
    from repro.configs.base import FedConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    from repro.models import small

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=16, samples_per_client=30)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)

    def mk():
        fed = FedConfig(total_clients=16, clients_per_round=6, rounds=10,
                        k0=6, eta0=0.3, batch_size=8, k_schedule="rounds",
                        k_quantize=True, seed=0, transport="int8",
                        downlink="int8")
        rt = RuntimeModel(task.model_size_mb, task.runtime, 6)
        return FedAvgTrainer(loss_fn, params, data, fed, rt)

    straight = mk()
    straight.run(10)

    first = mk()
    first.run(6)
    up_at_save = first.history.uplink_mbit[-1]
    down_at_save = first.history.downlink_mbit[-1]
    assert up_at_save > 0 and down_at_save > 0
    path = os.path.join(tmp_path, "wire")
    first.save_state(path)

    resumed = mk()
    resumed.restore_state(path)
    resumed.run(10, resume=True)

    # counters are cumulative and monotone across the seam: round 7 charges
    # ON TOP of the restored totals (no reset, no double-charge)
    assert resumed.history.uplink_mbit[:6] == straight.history.uplink_mbit[:6]
    assert resumed.history.uplink_mbit[6] > up_at_save
    assert resumed.history.downlink_mbit[6] > down_at_save
    assert resumed.history.uplink_mbit == straight.history.uplink_mbit
    assert resumed.history.downlink_mbit == straight.history.downlink_mbit
    assert len(resumed.history.downlink_mbit) == 10
    # both EF residuals + the broadcast reference survive bitwise
    for a, b in zip(jax.tree.leaves(straight.engine.transport_state),
                    jax.tree.leaves(resumed.engine.transport_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(straight.engine.downlink_state),
                    jax.tree.leaves(resumed.engine.downlink_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert straight.history.as_dict() == resumed.history.as_dict()


def test_checkpoint_preserves_straggler_rng_stream(tmp_path):
    """With heterogeneity > 0 the runtime model consumes lognormal draws
    every round — save/restore must continue that stream, or resumed
    wall-clock history diverges from the uninterrupted run."""
    from repro.configs import get_paper_task
    from repro.configs.base import FedConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    from repro.models import small

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=8, samples_per_client=20)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)

    def mk():
        fed = FedConfig(total_clients=8, clients_per_round=4, rounds=8,
                        k0=3, eta0=0.3, batch_size=8, k_schedule="fixed",
                        seed=0, transport="int8")
        rt = RuntimeModel(task.model_size_mb, task.runtime, 4,
                          heterogeneity=0.5, seed=7)
        return FedAvgTrainer(loss_fn, params, data, fed, rt)

    straight = mk()
    straight.run(8)
    first = mk()
    first.run(5)
    path = os.path.join(tmp_path, "het")
    first.save_state(path)
    resumed = mk()
    resumed.restore_state(path)
    resumed.run(8, resume=True)
    assert straight.history.wall_clock_s == resumed.history.wall_clock_s


def test_restore_backfills_downlink_mbit_for_old_checkpoints(tmp_path):
    """A pre-downlink checkpoint carries no ``history.downlink_mbit`` /
    ``down_mbit``; restore must backfill the new cumulative series with
    zeros so the per-round lists stay index-aligned."""
    import json

    from repro.configs import get_paper_task
    from repro.configs.base import FedConfig
    from repro.core import FedAvgTrainer, RuntimeModel
    from repro.models import small

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=8, samples_per_client=20)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    params = small.init_task_model(jax.random.PRNGKey(0), task)

    def mk():
        fed = FedConfig(total_clients=8, clients_per_round=4, rounds=6,
                        k0=2, eta0=0.3, batch_size=4, k_schedule="fixed",
                        loss_window=3, seed=0)
        return FedAvgTrainer(loss_fn, params, data, fed,
                             RuntimeModel(task.model_size_mb, task.runtime,
                                          4))

    first = mk()
    first.run(4)
    path = os.path.join(tmp_path, "old")
    first.save_state(path)
    # strip the downlink fields the way a pre-§8.6 checkpoint lacks them
    meta_path = os.path.join(path, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["down_mbit"], meta["history"]["downlink_mbit"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)

    resumed = mk()
    resumed.restore_state(path)
    h = resumed.history
    assert h.downlink_mbit == [0.0] * 4         # backfilled, index-aligned
    resumed.run(6, resume=True)
    assert len(h.downlink_mbit) == len(h.rounds) == 6
    assert h.downlink_mbit[4] > 0.0             # new rounds charge on top


def test_checkpoint_shape_mismatch_raises(tmp_path, rng):
    from repro.configs import get_arch
    from repro.models import registry
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(rng, cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params)
    wrong = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] + 1,) + x.shape[1:], x.dtype),
        params)
    with pytest.raises(ValueError):
        load_checkpoint(path, wrong)
