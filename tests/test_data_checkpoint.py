"""Data pipeline + checkpoint tests."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import make_lm_clients, make_paper_task, pipeline
from repro.data.partition import dirichlet_label_skew


def test_dirichlet_alpha_extremes(np_rng):
    skew = dirichlet_label_skew(np_rng, 50, 10, alpha=0.05)
    iid = dirichlet_label_skew(np_rng, 50, 10, alpha=1000.0)
    np.testing.assert_allclose(skew.sum(1), 1.0, rtol=1e-9)
    # low alpha concentrates mass; high alpha is near-uniform
    assert skew.max(axis=1).mean() > 0.6
    assert abs(iid.max(axis=1).mean() - 0.1) < 0.05


@pytest.mark.parametrize("name", ["sent140", "femnist", "cifar100",
                                  "shakespeare"])
def test_paper_task_generators(name, np_rng):
    data = make_paper_task(name, np_rng, num_clients=12, samples_per_client=20)
    assert data.num_clients == 12
    np.testing.assert_allclose(data.weights.sum(), 1.0, rtol=1e-6)
    assert len(data.val_y) > 0
    x0 = data.client_x[0]
    assert x0.shape[0] == 20
    if name == "shakespeare":
        assert data.client_y[0].shape == x0.shape      # next-token labels
        assert x0.max() < 79


def test_round_batches_shapes(np_rng):
    data = make_paper_task("femnist", np_rng, num_clients=10,
                           samples_per_client=30)
    ids = pipeline.sample_clients(np_rng, data, 4)
    assert len(set(ids)) == 4
    b = pipeline.round_batches(np_rng, data, ids, k=5, batch_size=8)
    assert b["x"].shape == (4, 5, 8, 784)
    assert b["y"].shape == (4, 5, 8)
    w = pipeline.client_weights(data, ids)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)


def test_lm_clients(np_rng):
    data = make_lm_clients(np_rng, num_clients=6, vocab=100, seq_len=16)
    assert data.client_x[0].shape == (64, 16)
    assert data.client_x[0].dtype == np.int32


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.configs import get_arch
    from repro.models import registry
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(rng, cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, meta={"round": 42, "k": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    restored, meta = load_checkpoint(path, like)
    assert meta["round"] == 42 and meta["k"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path, rng):
    from repro.configs import get_arch
    from repro.models import registry
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = registry.init(rng, cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params)
    wrong = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((x.shape[0] + 1,) + x.shape[1:], x.dtype),
        params)
    with pytest.raises(ValueError):
        load_checkpoint(path, wrong)
