"""End-to-end behaviour tests for the paper's system.

The headline claim (Table 4 / Figs 1-2): K-decay schedules reach comparable
or better training error in LESS simulated wall-clock and LESS total compute
than fixed-K FedAvg, on non-IID federated data.
"""
import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # long multi-round runs; see pytest.ini

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import FedAvgTrainer, RuntimeModel, make_eval_fn
from repro.data import make_paper_task
from repro.models import small


@pytest.fixture(scope="module")
def sent140():
    """The paper's convex task (fast on CPU)."""
    task = get_paper_task("sent140")
    data = make_paper_task("sent140", np.random.default_rng(0),
                           num_clients=40, samples_per_client=15)
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    return task, data, loss_fn


def run_schedule(sent140, k_schedule, eta_schedule="fixed", rounds=25):
    task, data, loss_fn = sent140
    fed = FedConfig(total_clients=40, clients_per_round=10, rounds=rounds,
                    k0=12, eta0=1.0, batch_size=8, loss_window=5,
                    k_schedule=k_schedule, eta_schedule=eta_schedule, seed=3)
    params = small.init_task_model(jax.random.PRNGKey(0), task)
    rt = RuntimeModel(task.model_size_mb, task.runtime, 10)
    tr = FedAvgTrainer(loss_fn, params, data, fed, rt,
                       eval_fn=make_eval_fn(loss_fn, data))
    return tr.run(rounds, eval_every=5)


def test_paper_headline_claim(sent140):
    """K-decay: comparable error, strictly less compute and wall-clock."""
    fixed = run_schedule(sent140, "fixed")
    decay = run_schedule(sent140, "rounds")
    # strictly fewer SGD steps and less wall-clock (Table 4 mechanism)
    assert decay.sgd_steps[-1] < 0.7 * fixed.sgd_steps[-1]
    assert decay.wall_clock_s[-1] < fixed.wall_clock_s[-1]
    # Fig. 1 is error-vs-TIME: compare at equal simulated wall-clock —
    # the best fixed-K loss achieved within decay's total time budget
    t_budget = decay.wall_clock_s[-1]
    fixed_at_t = min(l for l, t in zip(fixed.min_train_loss,
                                       fixed.wall_clock_s) if t <= t_budget)
    assert decay.min_train_loss[-1] <= fixed_at_t * 1.15
    # both learn
    assert fixed.min_train_loss[-1] < fixed.train_loss[0]
    assert decay.min_train_loss[-1] < decay.train_loss[0]


def test_eta_decay_comparison_runs(sent140):
    h = run_schedule(sent140, "fixed", eta_schedule="rounds", rounds=10)
    assert h.eta[0] == 1.0 and h.eta[-1] == pytest.approx(1.0 / np.sqrt(10))
    # eta-decay performs the SAME compute as fixed (paper Table 4 note)
    fixed = run_schedule(sent140, "fixed", rounds=10)
    assert h.sgd_steps[-1] == fixed.sgd_steps[-1]


def test_history_integrity(sent140):
    h = run_schedule(sent140, "rounds", rounds=8)
    assert len(h.rounds) == 8
    assert all(a <= b for a, b in zip(h.wall_clock_s, h.wall_clock_s[1:]))
    assert all(a <= b for a, b in zip(h.sgd_steps, h.sgd_steps[1:]))
    assert all(np.isfinite(h.train_loss))
