"""Eq. 3-5 runtime model + Table 4 compute accounting."""
import numpy as np
import pytest

from repro.configs import get_paper_task
from repro.configs.base import RuntimeModelConfig
from repro.core import RuntimeModel


def test_eq3_round_cost_homogeneous():
    rt = RuntimeModel(model_size_mbit=40.0,
                      cfg=RuntimeModelConfig(download_mbps=20, upload_mbps=5,
                                             beta_seconds=0.31),
                      clients_per_round=25)
    c = rt.round_cost(k=50)
    # |x|/D + K*beta + |x|/U = 2 + 15.5 + 8
    assert c.wall_clock_s == pytest.approx(2 + 50 * 0.31 + 8)
    assert c.sgd_steps == 50 * 25
    assert c.uplink_mbit == pytest.approx(40.0 * 25)


def test_eq5_total_time_additivity():
    rt = RuntimeModel(6.71, RuntimeModelConfig(beta_seconds=0.017), 60)
    ks = [80, 40, 20, 10]
    total = rt.total_time(ks)
    assert total == pytest.approx(sum(rt.round_cost(k).wall_clock_s for k in ks))


def test_straggler_model_is_slower():
    cfg = RuntimeModelConfig(beta_seconds=1.0)
    hom = RuntimeModel(5.0, cfg, clients_per_round=20, heterogeneity=0.0)
    het = RuntimeModel(5.0, cfg, clients_per_round=20, heterogeneity=0.5,
                       seed=1)
    hs = [het.round_cost(10).wall_clock_s for _ in range(50)]
    assert np.mean(hs) > hom.round_cost(10).wall_clock_s  # max over lognormals


def test_comm_time_is_het_free_mean_and_round_cost_charges_het_comm():
    """The two wall-clock paths reconcile: ``comm_time`` is the documented
    het-free per-round mean — at heterogeneity == 0 the Eq. 5 total
    re-derives exactly from per-round costs — while ``round_cost`` applies
    the client's speed multiplier to the WHOLE round (compute and both
    wire legs), so a pure-communication round still sees stragglers
    (previously the multiplier hit beta only and beta = 0 silently erased
    heterogeneity)."""
    cfg = RuntimeModelConfig(download_mbps=20, upload_mbps=5, beta_seconds=0.0)
    hom = RuntimeModel(40.0, cfg, clients_per_round=20, heterogeneity=0.0)
    assert hom.round_cost(10).wall_clock_s == pytest.approx(hom.comm_time())
    assert hom.total_time([10, 5, 2]) == pytest.approx(
        sum(hom.round_cost(k).wall_clock_s for k in (10, 5, 2)))
    het = RuntimeModel(40.0, cfg, clients_per_round=20, heterogeneity=0.8,
                       seed=3)
    walls = [het.round_cost(10).wall_clock_s for _ in range(20)]
    # beta == 0: every round is pure comm — the straggler max must still
    # exceed the het-free mean (max of 20 lognormal multipliers > 1)
    assert min(walls) > het.comm_time()
    assert het.comm_time() == pytest.approx(hom.comm_time())


def test_table4_relative_sgd_steps():
    rt = RuntimeModel(1.0, RuntimeModelConfig(), 10)
    k0 = 80
    ks_fixed = [k0] * 100
    ks_decay = [max(1, int(np.ceil(k0 / (r + 1) ** (1 / 3)))) for r in range(100)]
    rel = rt.relative_sgd_steps(ks_decay, k0)
    assert 0.05 < rel < 0.6            # K_r-rounds is aggressive (paper: 0.09-0.74)
    assert rt.relative_sgd_steps(ks_fixed, k0) == pytest.approx(1.0)


def test_paper_task_constants_table1_table2():
    t = get_paper_task("shakespeare")
    assert t.fed.k0 == 80 and t.fed.eta0 == 0.1
    assert t.runtime.beta_seconds == 1.5
    assert t.model_size_mb == 5.21
    assert get_paper_task("sent140").fed.total_clients == 21876
    assert get_paper_task("cifar100").runtime.beta_seconds == 0.31
    assert get_paper_task("femnist").fed.clients_per_round == 60
