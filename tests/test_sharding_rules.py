"""Sharding-rule unit tests (FakeMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

pytestmark = pytest.mark.slow      # eval_shape over every arch; see pytest.ini

from repro.configs import get_arch
from repro.distributed import sharding
from repro.models import registry


def specs_for(arch, mesh, two_d=False, fsdp_axes=("data",)):
    cfg = get_arch(arch)
    shapes = jax.eval_shape(
        lambda: registry.init(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    return cfg, shapes, sharding.param_pspecs(cfg, shapes, mesh, two_d=two_d,
                                              fsdp_axes=fsdp_axes)


def _axis_total(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def assert_divisible(shapes, specs, mesh):
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            n = _axis_total(mesh, entry)
            assert dim % n == 0, (leaf.shape, tuple(spec))


def test_every_arch_param_specs_divisible(mesh16x16):
    for arch in ("qwen2-7b", "gemma2-27b", "mamba2-780m", "zamba2-7b",
                 "phi3.5-moe-42b-a6.6b", "whisper-tiny", "llava-next-34b"):
        cfg, shapes, specs = specs_for(arch, mesh16x16)
        assert_divisible(shapes, specs, mesh16x16)


def test_2d_specs_divisible(mesh16x16):
    for arch in ("mixtral-8x22b", "nemotron-4-340b"):
        cfg, shapes, specs = specs_for(arch, mesh16x16, two_d=True)
        assert_divisible(shapes, specs, mesh16x16)


def test_col_row_parallel_orientation(mesh16x16):
    cfg, shapes, specs = specs_for("qwen2-7b", mesh16x16)
    stack = specs["stack"]["b0"]
    # col-parallel: wq kernel (lead, in, out) -> out sharded
    assert tuple(stack["attn"]["wq"]["kernel"])[-1] == "model"
    # row-parallel: wo kernel -> in sharded
    assert tuple(stack["attn"]["wo"]["kernel"])[-2] == "model"
    assert tuple(stack["mlp"]["down"]["kernel"])[-2] == "model"
    # embedding: vocab sharded
    assert tuple(specs["embed"]["embedding"])[0] == "model"


def test_expert_parallel_when_divisible(mesh16x16):
    cfg, shapes, specs = specs_for("phi3.5-moe-42b-a6.6b", mesh16x16)
    # 16 experts over 16-way model axis -> expert parallelism
    assert tuple(specs["stack"]["b0"]["moe"]["gate"])[-3] == "model"
    cfg2, shapes2, specs2 = specs_for("mixtral-8x22b", mesh16x16, two_d=True)
    # 8 experts don't divide 16 -> wide FFN dim sharded instead
    g = tuple(specs2["stack"]["b0"]["moe"]["gate"])
    assert g[-3] is None and g[-1] == "model"


def test_cache_rules(mesh16x16):
    cfg = get_arch("qwen2-7b")   # kv=4: not divisible by 16 -> head_dim shard
    cache = registry.cache_specs(cfg, batch=128, max_seq=1024)
    specs = sharding.cache_pspecs(cfg, cache, mesh16x16)
    k_spec = tuple(jax.tree.leaves(specs,
                                   is_leaf=lambda x: isinstance(x, P))[0])
    assert "model" in k_spec            # something IS model-sharded
    cfg2 = get_arch("gemma2-27b")       # kv=16 -> head shard
    cache2 = registry.cache_specs(cfg2, batch=128, max_seq=1024)
    specs2 = sharding.cache_pspecs(cfg2, cache2, mesh16x16)
    leaf = jax.tree.leaves(specs2, is_leaf=lambda x: isinstance(x, P))[0]
    assert tuple(leaf)[-2] == "model"   # kv-head axis


def test_ssm_state_rules(mesh16x16):
    cfg = get_arch("mamba2-780m")       # 48 ssm heads / 16 OK
    cache = registry.cache_specs(cfg, batch=128, max_seq=64)
    specs = sharding.cache_pspecs(cfg, cache, mesh16x16)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys[-1] == "ssm":
            assert tuple(spec)[-3] == "model"     # heads sharded
