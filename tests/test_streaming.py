"""Chunked streaming cohort tests (DESIGN.md §11).

The contract: ``fed.cohort_chunk=C`` processes the round's U clients in
C-sized slabs folded into streaming f32 accumulators and must stay
equivalent to the dense vmapped round — bitwise when C == U (the single
slab preserves the dense summation order), within f32 partial-sum-reorder
tolerance otherwise. ``cohort_chunk=None`` must leave the compiled
program untouched (executable-key identity), chunking must refuse the
configurations it cannot honour (robust aggregators, downlink codecs,
mesh-sequential), the streamed round must actually shrink peak executable
memory, and checkpoints must never see mid-round slab state — a dense
checkpoint resumes bitwise into a chunked trainer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build
from repro.api.spec import SpecValidationError

COHORT = 6


def _spec(chunk=None, transport="none", sampler="uniform", *,
          backend="local", strategy="parallel", aggregator="mean",
          rounds=4, clients=12, cohort=COHORT, bucket_rounds=2,
          downlink="none"):
    d = {
        "data": {"kind": "paper", "task": "femnist", "clients": clients,
                 "samples_per_client": 8, "seed": 0},
        "fed": {"clients_per_round": cohort, "rounds": rounds, "k0": 2,
                "eta0": 0.3, "batch_size": 4, "eval_every": 0,
                "aggregator": aggregator, "bucket_rounds": bucket_rounds,
                "loss_window": 3, "seed": 0},
        "transport": {"name": transport, "downlink": downlink},
        "sampler": {"name": sampler},
        "backend": {"name": backend, "strategy": strategy},
    }
    if sampler == "fixed_cohort":
        d["sampler"]["cohort"] = list(range(cohort))
    if chunk is not None:
        d["fed"]["cohort_chunk"] = chunk
    return ExperimentSpec.from_dict(d)


def _run(spec):
    exp = build(spec)
    exp.run()
    return exp


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _max_abs(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# chunk invariance: cohort_chunk in {1, 3, U} vs the dense round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport,sampler", [
    ("none", "uniform"),            # transportless streaming fold
    ("int8", "uniform"),            # codec + server-aggregate EF residual
    ("int8", "fixed_cohort"),       # codec + per-client EF slab slices
    ("topk", "fixed_cohort"),       # sparse codec + per-client EF
])
def test_chunk_invariance_local(transport, sampler):
    dense = _run(_spec(None, transport, sampler))
    # C == U: one slab, dense summation order preserved => bitwise
    full = _run(_spec(COHORT, transport, sampler))
    _assert_bitwise(full.params, dense.params)
    _assert_bitwise(full.trainer.engine.transport_state,
                    dense.trainer.engine.transport_state)
    # sub-cohort slabs: only the f32 partial-sum order differs; for int8/
    # topk the EF residual then re-quantises the reordered sum, so the
    # codec tolerance is a few quantisation ULPs rather than f32 eps
    tol = 1e-6 if transport == "none" else 2e-3
    for c in (1, 3):
        chunked = _run(_spec(c, transport, sampler))
        assert _max_abs(chunked.params, dense.params) <= tol, \
            f"cohort_chunk={c} diverged beyond streaming tolerance"


def test_chunk_invariance_kernel_aggregator():
    # the Pallas reduce is the other LINEAR aggregator; C == U stays bitwise
    dense = _run(_spec(None, aggregator="kernel"))
    _assert_bitwise(_run(_spec(COHORT, aggregator="kernel")).params,
                    dense.params)
    assert _max_abs(_run(_spec(3, aggregator="kernel")).params,
                    dense.params) <= 1e-6


def test_chunk_invariance_mesh_parallel():
    dense = _run(_spec(None, "int8", backend="mesh"))
    _assert_bitwise(_run(_spec(COHORT, "int8", backend="mesh")).params,
                    dense.params)
    assert _max_abs(_run(_spec(3, "int8", backend="mesh")).params,
                    dense.params) <= 2e-3


def test_chunked_matches_across_bucket_rounds():
    """The scheduler forces bucket_cap=1 under chunking; bucketing is
    execution detail, so dense bucket_rounds=4 == chunked regardless."""
    dense = _run(_spec(None, bucket_rounds=4))
    _assert_bitwise(_run(_spec(COHORT, bucket_rounds=4)).params,
                    dense.params)


# ---------------------------------------------------------------------------
# loud refusals: configurations streaming slabs cannot honour
# ---------------------------------------------------------------------------

def test_chunking_rejects_robust_aggregator():
    with pytest.raises(SpecValidationError, match="running weighted sum"):
        _spec(3, aggregator="median").validate()
    with pytest.raises(SpecValidationError, match="running weighted sum"):
        _spec(3, aggregator="trimmed_mean").validate()


def test_chunking_rejects_downlink_codec():
    with pytest.raises(SpecValidationError, match="downlink"):
        _spec(3, "int8", downlink="int8").validate()


def test_chunking_rejects_mesh_sequential():
    with pytest.raises(SpecValidationError, match="sequential"):
        _spec(3, backend="mesh", strategy="sequential").validate()


def test_engine_guard_rejects_robust_chunk():
    """Defence in depth below the spec layer: the engine itself refuses."""
    from repro.configs import get_paper_task
    from repro.core.engine.round import RoundEngine
    from repro.models import small

    task = get_paper_task("femnist")
    loss_fn = lambda p, b: small.task_loss(p, task, b)
    with pytest.raises(ValueError, match="running weighted sum"):
        RoundEngine(loss_fn, aggregator="median", cohort_chunk=2)


# ---------------------------------------------------------------------------
# cohort_chunk=None: the compiled program is untouched
# ---------------------------------------------------------------------------

def test_chunk_none_program_identical():
    base = _run(_spec())                 # no cohort_chunk key at all
    off = _run(_spec(None))              # explicit None — same thing
    keys_base = set(base.trainer.engine._executables)
    keys_off = set(off.trainer.engine._executables)
    assert keys_base == keys_off
    assert not any(k[0] in ("slab", "slabfin") for k in keys_base)
    _assert_bitwise(base.params, off.params)


def test_chunked_compiles_slab_executables():
    exp = _run(_spec(3))
    tags = {k[0] for k in exp.trainer.engine._executables}
    assert "slab" in tags and "slabfin" in tags
    # ragged tail slab (6 = 3 + 3 here: none) vs even slabs share one
    # executable per shape; chunk=4 over 6 clients adds the ragged shape
    exp2 = _run(_spec(4))
    slab_keys = [k for k in exp2.trainer.engine._executables
                 if k[0] == "slab"]
    assert len(slab_keys) == 2           # full slab (4) + ragged tail (2)


# ---------------------------------------------------------------------------
# memory: the streamed round must actually shrink the executable
# ---------------------------------------------------------------------------

def test_chunked_peak_memory_budget():
    """chunk = U/8 must cut peak executable bytes >= 4x (ISSUE acceptance:
    the chunked program never materialises the (U, K, b, ...) stack)."""
    from repro.core import trainer_peak_mb

    def spec(chunk):
        return _spec(chunk, clients=32, cohort=16, rounds=2,
                     bucket_rounds=1)

    dense = _run(spec(None))
    chunked = _run(spec(2))
    dense_mb = trainer_peak_mb(dense.trainer)
    chunk_mb = trainer_peak_mb(chunked.trainer)
    assert dense_mb > 0 and chunk_mb > 0
    assert dense_mb / chunk_mb >= 4.0, \
        f"peak {dense_mb:.2f}MB -> {chunk_mb:.2f}MB: reduction under 4x"


# ---------------------------------------------------------------------------
# checkpoints: mid-round slab state never persists
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport,sampler", [
    ("none", "uniform"),
    ("int8", "fixed_cohort"),            # per-client EF rides the checkpoint
])
def test_dense_checkpoint_resumes_bitwise_into_chunked(tmp_path, transport,
                                                       sampler):
    """Slab accumulators are round-atomic (commit at finalize), so trainer
    state after round r is identical dense vs chunked-at-C=U — a dense
    mid-schedule checkpoint restored into a chunked trainer continues
    bitwise."""
    straight = _run(_spec(None, transport, sampler))        # dense, 4 rounds

    half = build(_spec(None, transport, sampler))
    half.run(rounds=2)
    path = str(tmp_path / "dense2")
    half.trainer.save_state(path)

    cont = build(_spec(COHORT, transport, sampler))
    cont.trainer.restore_state(path)
    cont.trainer.run(4, resume=True)
    _assert_bitwise(cont.params, straight.params)
    _assert_bitwise(cont.trainer.engine.transport_state,
                    straight.trainer.engine.transport_state)
    assert straight.history.as_dict() == cont.history.as_dict()


def test_chunked_checkpoint_state_is_round_aligned(tmp_path):
    """What a chunked trainer persists is full-round state: the per-client
    EF tree keeps its (U, ...) leading dim (never a slab slice), and the
    saved checkpoint continues bitwise vs an uninterrupted chunked run."""
    spec = _spec(2, "int8", "fixed_cohort")
    straight = _run(spec)

    half = build(spec)
    half.run(rounds=2)
    ef_lead = jax.tree.leaves(half.trainer.engine.transport_state)[0].shape[0]
    assert ef_lead == COHORT             # U slots, not the slab's 2
    path = str(tmp_path / "chunk2")
    half.trainer.save_state(path)

    cont = build(spec)
    cont.trainer.restore_state(path)
    cont.trainer.run(4, resume=True)
    _assert_bitwise(cont.params, straight.params)
    _assert_bitwise(cont.trainer.engine.transport_state,
                    straight.trainer.engine.transport_state)


# ---------------------------------------------------------------------------
# prefetch: slab double-buffering must not change the stream
# ---------------------------------------------------------------------------

def test_chunked_prefetch_matches_sync():
    spec = _spec(3, "int8")
    pre = _run(spec)
    sync = _run(spec.with_overrides("fed.prefetch=false"))
    _assert_bitwise(pre.params, sync.params)
