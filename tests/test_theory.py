"""The paper's theory, executed: Theorems 1-2, Corollary 2.1."""
import math

import numpy as np
import pytest

from repro.core.theory import (ProblemConstants, optimal_eta, optimal_eta_rounds,
                               optimal_k, optimal_k_rounds, theorem1_bound)

PC = ProblemConstants(L=4.0, mu=1.0, sigma_sq=0.5, gamma=0.2, g_sq=2.0,
                      f0=10.0, f_star=0.0, n_clients=10)


def test_theorem2_k_decays_as_cube_root_of_time():
    ks = [optimal_k(PC, eta=0.05, f_current=10.0, comm_time_s=2.0,
                    horizon_s=w) for w in (1, 8, 64)]
    # K* ~ W^{-1/3}: doubling horizon 8x halves K*
    assert ks[0] / ks[1] == pytest.approx(2.0, rel=1e-6)
    assert ks[1] / ks[2] == pytest.approx(2.0, rel=1e-6)


def test_corollary21_eta_decays_as_sqrt_of_time():
    es = [optimal_eta(PC, k=8, f_current=10.0, comm_time_s=2.0, beta_s=0.1,
                      horizon_s=w) for w in (1, 4, 16)]
    assert es[0] / es[1] == pytest.approx(2.0, rel=1e-6)
    assert es[1] / es[2] == pytest.approx(2.0, rel=1e-6)


def test_k_rounds_form_independent_of_beta():
    # Eq. 10 depends only on R (communication-dominated regime)
    a = optimal_k_rounds(PC, eta=0.05, rounds=100)
    assert a == pytest.approx(optimal_k_rounds(PC, eta=0.05, rounds=100))
    assert optimal_k_rounds(PC, eta=0.05, rounds=800) == pytest.approx(a / 2)


def test_theorem1_bound_structure():
    # first term ~ 1/T, second constant in T: bound decreases to a floor
    b1 = theorem1_bound(PC, eta=0.01, ks=[8] * 10)
    b2 = theorem1_bound(PC, eta=0.01, ks=[8] * 1000)
    assert b2 < b1
    # larger K inflates the drift term (sum K^3 / sum K ~ K^2)
    small_k = theorem1_bound(PC, eta=0.01, ks=[2] * 1000)
    big_k = theorem1_bound(PC, eta=0.01, ks=[32] * 1000)
    assert big_k > small_k
    # decaying K sits between the fixed extremes
    dec = theorem1_bound(PC, eta=0.01,
                         ks=[max(2, int(32 / (r + 1) ** (1 / 3)))
                             for r in range(1000)])
    assert small_k <= dec <= big_k


def test_theorem1_bound_holds_on_quadratic_fedavg():
    """Simulate FedAvg on a strongly-convex quadratic and check the measured
    min gradient norm is below the Theorem 1 bound."""
    rng = np.random.default_rng(0)
    dim, n_clients = 4, 10
    # client objectives f_c(x) = 0.5 (x - b_c)^T A (x - b_c), A = diag in [mu, L]
    diag = np.linspace(1.0, 4.0, dim)
    bs = rng.normal(size=(n_clients, dim)) * 0.5
    b_bar = bs.mean(axis=0)

    def grad(x, c):
        return diag * (x - bs[c])

    def global_grad(x):
        return diag * (x - b_bar)

    def F(x):
        return 0.5 * np.mean([np.sum(diag * (x - b)**2) for b in bs])

    x0 = np.full(dim, 3.0)
    f_star = F(b_bar)
    g_sq = (4.0 ** 2) * float(np.sum((x0 - b_bar) ** 2))
    pc = ProblemConstants(L=4.0, mu=1.0, sigma_sq=0.0,
                          gamma=F(b_bar) - 0.0, g_sq=g_sq, f0=F(x0),
                          f_star=f_star, n_clients=n_clients)

    eta = 1 / (4 * pc.L)
    ks = [max(1, int(8 / (r + 1) ** (1 / 3))) for r in range(50)]
    x = x0.copy()
    min_gn = np.inf
    for k in ks:
        clients = []
        for c in range(n_clients):
            xc = x.copy()
            for _ in range(k):
                xc -= eta * grad(xc, c)
            clients.append(xc)
        x = np.mean(clients, axis=0)
        min_gn = min(min_gn, float(np.sum(global_grad(x) ** 2)))

    bound = theorem1_bound(pc, eta=eta, ks=ks)
    assert min_gn <= bound
