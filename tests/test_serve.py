"""Serve-while-training (DESIGN.md §14): GlobalModelStore snapshot
contract across the downlink/ref-store matrix and both backends, serving
read-only program identity, legacy-checkpoint restore, the live serving
loop, scheduler serve cuts, and the spec/launcher refusal surface."""
import json
import os

import jax
import numpy as np
import pytest

from repro.api import ExperimentSpec, build
from repro.api.experiment import FederatedExperiment
from repro.api.spec import SpecValidationError
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import RuntimeModel
from repro.core.engine.model_store import GlobalModelStore
from repro.core.engine.round import ExecutableRegistry
from repro.core.engine.scheduler import RoundScheduler
from repro.core.schedules import DecayController

PAPER = ("data.kind=paper", "data.task=femnist", "data.clients=16",
         "data.samples_per_client=16", "fed.clients_per_round=6",
         "fed.rounds=4", "fed.k0=3", "fed.batch_size=8",
         "fed.k_schedule=rounds", "fed.bucket_rounds=2", "fed.eval_every=0")

LM = ("model.arch=qwen1.5-0.5b", "model.reduced=true", "data.kind=lm",
      "data.clients=8", "data.samples_per_client=8", "data.seq_len=16",
      "fed.rounds=3", "fed.clients_per_round=4", "fed.k0=2",
      "fed.batch_size=4", "fed.k_schedule=rounds", "fed.bucket_rounds=2",
      "runtime.beta_seconds=0.05")


def paper_spec(*extra):
    return ExperimentSpec().with_overrides(*PAPER, *extra)


def lm_spec(*extra):
    return ExperimentSpec().with_overrides(*LM, *extra)


def assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert (np.asarray(x) == np.asarray(y)).all()


# ---------------------------------------------------------------------------
# snapshot contract: the exact tree clients hold, across the store bracket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["local", "mesh"])
@pytest.mark.parametrize("downlink,ref_store", [
    ("none", "f32"), ("int8", "f32"), ("int8", "q8"),
    ("adaptive", "f32"), ("adaptive", "q8")])
def test_snapshot_matches_client_tree(backend, downlink, ref_store):
    """snapshot() returns (version, client-view tree): the raw params when
    there is no downlink codec, else the dequantised broadcast reference —
    bitwise, repeatably, and without mutating any server state."""
    exp = build(paper_spec(f"backend.name={backend}",
                           "transport.name=int8",
                           f"transport.downlink={downlink}",
                           f"transport.ref_store={ref_store}"))
    exp.run()
    tr = exp.trainer
    store = tr.store
    assert store.version == 4                    # one bump per round
    v, tree = store.snapshot()
    assert v == store.version

    if downlink == "none":
        assert_trees_bitwise(tree, tr.params)
    else:
        state = tr.engine.downlink_state
        ref_before = [np.array(x, copy=True)
                      for x in jax.tree.leaves(state["ref"])]
        dl = tr.engine.downlink
        assert_trees_bitwise(tree, dl.load_tree(state["ref"],
                                                like=tr.params))
        if ref_store == "f32":
            # identity ref store: the snapshot IS the stored reference
            assert_trees_bitwise(tree, state["ref"])
        # snapshot is read-only: stored reference untouched, and a second
        # snapshot reproduces the first bitwise
        for a, b in zip(ref_before, jax.tree.leaves(state["ref"])):
            assert (a == np.asarray(b)).all()
    v2, tree2 = store.snapshot()
    assert v2 == v
    assert_trees_bitwise(tree2, tree)


def test_async_snapshot_mid_buffer():
    """Async engine: snapshot mid-simulation (part-filled buffer, pending
    events) returns the applied params bitwise with version == number of
    buffer applications."""
    exp = build(paper_spec("fed.rounds=8", "fed.aggregation=async",
                           "fed.buffer_size=3", "fed.staleness_weight=inv",
                           "runtime.heterogeneity=0.7"))
    exp.trainer.run(5)
    tr = exp.trainer
    assert tr._buf_count != 0 or tr._heap        # genuinely mid-buffer
    v, tree = tr.store.snapshot()
    assert v == tr.store.version == tr._version
    assert_trees_bitwise(tree, tr.params)


# ---------------------------------------------------------------------------
# store extraction is invisible to programs and checkpoints
# ---------------------------------------------------------------------------

def test_serving_read_only_program_identity():
    """Attaching the serving loop (downlink='none', sync aggregation) must
    not touch the traced programs: AOT executable keys bit-for-bit, params
    bitwise, train history equal to the serve-off run. serve_every cuts the
    bucket plan (that IS the staleness bound), so the comparison pins
    bucket_rounds=1 to hold the plan fixed on both sides."""
    from repro.api.sweep import spec_program_key
    off = lm_spec("fed.bucket_rounds=1")
    on = lm_spec("fed.bucket_rounds=1", "serve.every=1")
    assert spec_program_key(off) == spec_program_key(on)

    reg_off, reg_on = ExecutableRegistry(), ExecutableRegistry()
    h_off = build(off, registry=reg_off).run()
    exp_on = build(on, registry=reg_on)
    h_on = exp_on.run()

    assert set(reg_off._entries) == set(reg_on._entries)
    assert h_on.train_loss == h_off.train_loss
    assert h_on.sgd_steps == h_off.sgd_steps
    assert h_on.uplink_mbit == h_off.uplink_mbit
    # ... and the serving side actually served
    assert h_on.serve_rounds == [1, 2, 3]
    assert all(t > 0 for t in h_on.serve_tokens_per_sec)
    assert max(h_on.serve_staleness) <= 1        # absorb-before-tick bound
    assert exp_on.trainer.serving.served_version == \
        exp_on.trainer.store.version


@pytest.mark.parametrize("aggregation", ["sync", "async"])
def test_legacy_checkpoint_restores_bitwise(tmp_path, aggregation):
    """A pre-store checkpoint (no store_version / serve_queries meta keys)
    restores through GlobalModelStore.state_dict's legacy fallback and
    continues bitwise."""
    extra = (("transport.name=int8", "transport.downlink=int8",
              "transport.ref_store=q8") if aggregation == "sync" else
             ("fed.aggregation=async", "fed.buffer_size=3",
              "runtime.heterogeneity=0.7", "fed.rounds=8"))
    spec = paper_spec(*extra)
    rounds = spec.fed.rounds
    ref = build(spec)
    href = ref.run()

    a = build(spec)
    a.trainer.run(rounds // 2)
    ck = os.path.join(tmp_path, "ck")
    a.save(ck)
    meta_path = os.path.join(ck, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    assert "store_version" in meta               # written by the store
    for k in ("store_version", "serve_queries"):
        meta.pop(k, None)                        # back to the pre-store format
    with open(meta_path, "w") as f:
        json.dump(meta, f)

    b = FederatedExperiment.restore(ck)
    hb = b.trainer.run(rounds, resume=True)
    assert hb.train_loss == href.train_loss      # bitwise, not approx
    assert hb.wall_clock_s == href.wall_clock_s
    assert hb.uplink_mbit == href.uplink_mbit
    assert_trees_bitwise(b.trainer.params, ref.trainer.params)
    # version fallback: completed rounds (sync) / applied updates (async)
    assert b.trainer.store.version > 0


def test_checkpoint_roundtrip_keeps_store_counters(tmp_path):
    spec = paper_spec("transport.name=int8", "transport.downlink=int8")
    a = build(spec)
    a.run()
    ck = os.path.join(tmp_path, "ck")
    a.save(ck)
    b = FederatedExperiment.restore(ck)
    for attr in ("version", "wall", "steps", "up_mbit", "down_mbit",
                 "min_loss", "max_acc", "serve_queries"):
        assert getattr(b.trainer.store, attr) == \
            getattr(a.trainer.store, attr)
    assert_trees_bitwise(b.trainer.params, a.trainer.params)
    assert_trees_bitwise(b.trainer.engine.downlink_state["ref"],
                         a.trainer.engine.downlink_state["ref"])


# ---------------------------------------------------------------------------
# scheduler serve cuts
# ---------------------------------------------------------------------------

def test_scheduler_serve_cuts_and_flags():
    fed = FedConfig(total_clients=8, clients_per_round=4, rounds=8, k0=4,
                    eta0=0.1, batch_size=4, k_schedule="fixed",
                    bucket_rounds=8, seed=0)
    plan = list(RoundScheduler(DecayController(fed), fed, total_rounds=8,
                               serve_every=2).plan())
    # cap = min(bucket_rounds, serve_every): every bucket ends on a serve
    # round and is flagged for immediate absorb + hot-swap
    assert [b.rounds for b in plan] == [[1, 2], [3, 4], [5, 6], [7, 8]]
    assert all(b.serve_after for b in plan)
    # serve off: identical plan shape to the historical scheduler, no flags
    plan_off = list(RoundScheduler(DecayController(fed), fed,
                                   total_rounds=8).plan())
    assert [b.rounds for b in plan_off] == [[1, 2, 3, 4, 5, 6, 7, 8]]
    assert not any(b.serve_after for b in plan_off)
    # serve_every=3 over 8 rounds: cuts at 3 and 6 only
    plan3 = list(RoundScheduler(DecayController(fed), fed, total_rounds=8,
                                serve_every=3).plan())
    assert [b.serve_after for b in plan3] == \
        [b.rounds[-1] % 3 == 0 for b in plan3]


# ---------------------------------------------------------------------------
# runtime model: mixed train+serve cost
# ---------------------------------------------------------------------------

def test_runtime_model_serve_stretch():
    kw = dict(model_size_mbit=40.0, cfg=RuntimeModelConfig(beta_seconds=0.5),
              clients_per_round=4)
    base = RuntimeModel(**kw).round_cost(8)
    served = RuntimeModel(**kw, serve_qps=100.0,
                          serve_query_s=0.002).round_cost(8)
    rho = 100.0 * 0.002
    assert served.wall_clock_s == pytest.approx(
        base.wall_clock_s / (1.0 - rho))
    assert served.serve_queries == pytest.approx(
        100.0 * served.wall_clock_s)
    assert base.serve_queries == 0.0
    with pytest.raises(ValueError, match="rho"):
        RuntimeModel(**kw, serve_qps=500.0, serve_query_s=0.002)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------

def test_serve_spec_validation_errors():
    def errs(*ov):
        with pytest.raises(SpecValidationError) as ei:
            ExperimentSpec().with_overrides(*LM, *ov).validate()
        return "\n".join(ei.value.errors)

    assert "serve.every" in errs("serve.every=-1")
    assert "serve.qps" in errs("serve.qps=1.0")          # qps without loop
    assert "rho" in errs("serve.every=1", "serve.qps=600.0",
                         "serve.query_ms=2.0")
    assert "serve.traffic" in errs("serve.every=1", "serve.traffic=nope")
    assert "serve.batch" in errs("serve.every=1", "serve.batch=0")
    with pytest.raises(SpecValidationError, match="data.kind"):
        paper_spec("serve.every=1").validate()
    # the defaults and a valid serving config pass
    lm_spec().validate()
    lm_spec("serve.every=2", "serve.qps=50.0",
            "serve.query_ms=2.0").validate()


def test_traffic_registry_synthetic_deterministic():
    from repro.api.registries import TRAFFIC_REGISTRY
    assert "synthetic" in TRAFFIC_REGISTRY

    class Cfg:
        vocab_size = 97
    t = TRAFFIC_REGISTRY.get("synthetic")(cfg=Cfg(), batch=3, prompt_len=5,
                                          seed=11)
    a, b = t(4), t(4)
    assert a.shape == (3, 5) and a.dtype == np.int32
    assert (a == b).all()                        # pure in (seed, tick)
    assert not (t(5) == a).all()
    t2 = TRAFFIC_REGISTRY.get("synthetic")(cfg=Cfg(), batch=3, prompt_len=5,
                                           seed=12)
    assert not (t2(4) == a).all()


# ---------------------------------------------------------------------------
# the serve launcher: spec-embedded checkpoints, arch conflicts
# ---------------------------------------------------------------------------

def test_serve_launcher_rebuilds_from_embedded_spec(tmp_path, capsys):
    from repro.launch import serve as serve_launcher
    spec = lm_spec("serve.every=1")
    exp = build(spec)
    exp.run()
    ck = os.path.join(tmp_path, "ck")
    exp.save(ck)

    serve_launcher.main(["--checkpoint", ck, "--batch", "2",
                         "--prompt-len", "4", "--tokens", "4"])
    out = capsys.readouterr().out
    assert "rebuilt qwen1.5-0.5b" in out
    assert "tok/s" in out

    with pytest.raises(SystemExit, match="conflicts with the"):
        serve_launcher.main(["--checkpoint", ck, "--arch", "zamba2-7b"])

    # the served params are the checkpoint's params, not a fresh init
    cfg, params = serve_launcher.load_serving_params(ck)
    assert_trees_bitwise(params, exp.trainer.params)
    assert cfg.name == "qwen1.5-0.5b-reduced"


def test_store_standalone_snapshot():
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    store = GlobalModelStore(params=params)
    v, tree = store.snapshot()
    assert v == 0
    assert_trees_bitwise(tree, params)
    store.advance(3)
    assert store.snapshot()[0] == 3
