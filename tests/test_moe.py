"""MoE layer: dispatch paths vs the dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import moe


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("mixtral-8x22b").reduced()
    # generous capacity so no tokens drop => dispatch == dense exactly
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    return cfg, params, x


def test_dispatch_matches_dense(setup):
    cfg, params, x = setup
    yd, auxd = moe.moe_apply_dense(params, cfg, x)
    ys, auxs = moe.moe_apply_dispatch(params, cfg, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(auxs), float(auxd), rtol=1e-5)


def test_sharded_dispatch_matches_dispatch(setup):
    cfg, params, x = setup
    y1, _ = moe.moe_apply_dispatch(params, cfg, x)
    y2, _ = moe.moe_apply_dispatch_sharded(params, cfg, x, shards=4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


def test_kernel_path_matches(setup):
    cfg, params, x = setup
    y1, _ = moe.moe_apply_dispatch(params, cfg, x)
    y2, _ = moe.moe_apply_dispatch(params, cfg, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_are_bounded(setup):
    cfg, params, x = setup
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    y, _ = moe.moe_apply_dispatch(params, tight, x)
    yd, _ = moe.moe_apply_dense(params, tight, x)
    # dropped tokens make outputs differ, but most tokens survive
    close = np.isclose(np.asarray(y), np.asarray(yd), rtol=1e-3,
                       atol=1e-3).mean()
    assert close > 0.5


def test_aux_loss_favours_balance(setup):
    cfg, params, x = setup
    # uniform router => aux ~ 1 (its minimum); a collapsed router is higher
    T = 64
    xf = jax.random.normal(jax.random.PRNGKey(2), (T, cfg.d_model))
    _, _, aux_rand = moe._route(params, cfg, xf * 0.0)   # logits ~0 => uniform
    p_collapsed = jax.tree.map(lambda v: v, params)
    p_collapsed = {**params, "router": {"kernel":
                   params["router"]["kernel"] * 0.0 +
                   jnp.eye(cfg.d_model, cfg.moe.num_experts) * 100}}
    _, _, aux_col = moe._route(p_collapsed, cfg,
                               jnp.abs(xf) @ jnp.eye(cfg.d_model))
    assert float(aux_rand) <= float(aux_col) + 1e-3
