"""Quickstart: FedAvg with a decaying number of local SGD steps.

Trains the paper's FEMNIST DNN on a synthetic non-IID federated split and
compares the K_r-rounds decay schedule (Eq. 10) against fixed-K, reporting
simulated wall-clock (the paper's Eq. 5 runtime model) and total compute.

    PYTHONPATH=src python examples/quickstart.py [--rounds 60]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_paper_task
from repro.configs.base import FedConfig
from repro.core import FedAvgTrainer, RuntimeModel, make_eval_fn
from repro.data import make_paper_task
from repro.models import small


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=40)
    args = ap.parse_args()

    task = get_paper_task("femnist")
    data = make_paper_task("femnist", np.random.default_rng(0),
                           num_clients=args.clients, samples_per_client=60)
    loss_fn = lambda p, b: small.task_loss(p, task, b)

    results = {}
    for schedule in ("fixed", "rounds"):
        fed = FedConfig(total_clients=args.clients, clients_per_round=10,
                        rounds=args.rounds, k0=16, eta0=0.3, batch_size=16,
                        loss_window=8, k_schedule=schedule)
        params = small.init_task_model(jax.random.PRNGKey(0), task)
        rt = RuntimeModel(task.model_size_mb, task.runtime, 10)
        trainer = FedAvgTrainer(loss_fn, params, data, fed, rt,
                                eval_fn=make_eval_fn(loss_fn, data))
        print(f"\n=== schedule: K_r-{schedule} ===")
        h = trainer.run(args.rounds, eval_every=10, verbose=True)
        results[schedule] = h

        print(f"    ({trainer.compile_count} bucket executables compiled "
              f"for {args.rounds} rounds)")

    f, d = results["fixed"], results["rounds"]
    print("\n=== summary (paper's headline claim) ===")
    print(f"fixed-K : loss={f.min_train_loss[-1]:.4f} "
          f"acc={f.max_val_acc[-1]:.3f} simW={f.wall_clock_s[-1]:.0f}s "
          f"steps={f.sgd_steps[-1]}")
    print(f"K-decay : loss={d.min_train_loss[-1]:.4f} "
          f"acc={d.max_val_acc[-1]:.3f} simW={d.wall_clock_s[-1]:.0f}s "
          f"steps={d.sgd_steps[-1]}")
    print(f"compute saved: {1 - d.sgd_steps[-1] / f.sgd_steps[-1]:.0%}, "
          f"wall-clock saved: {1 - d.wall_clock_s[-1] / f.wall_clock_s[-1]:.0%}")


if __name__ == "__main__":
    main()
