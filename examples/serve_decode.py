"""Serving example: batched autoregressive decode with a KV/SSM cache.

Loads (or initialises) a reduced assigned architecture and decodes a batch
of token streams — the CPU-scale version of the serve_step exercised by
decode_32k / long_500k dry-runs. Works for dense, GQA, MoE, SSM and hybrid
archs (pick with --arch).

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rng = jax.random.PRNGKey(0)
    params = registry.init(rng, cfg)
    B, max_seq = args.batch, args.prompt_len + args.tokens

    if cfg.arch_type == "audio":
        audio = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        cache = registry.init_cache(params, cfg, B, max_seq, audio_embeds=audio)
    else:
        cache = registry.init_cache(params, cfg, B, max_seq)
    step = jax.jit(registry.decode_fn(cfg, moe_path="dense"))

    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                0, cfg.vocab_size)
    # teacher-forced prefill via the decode path (CPU-scale)
    tok = prompt[:, 0]
    for pos in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, pos], jnp.int32(pos))

    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1)
    for i in range(args.tokens):
        logits, cache = step(params, cache, tok,
                             jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0

    toks = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} ({cfg.arch_type}) batch={B} "
          f"decoded {args.tokens} tokens/seq")
    print(f"throughput: {B * args.tokens / dt:.1f} tok/s (CPU, reduced config)")
    print("sampled ids[0]:", toks[0].tolist())


if __name__ == "__main__":
    main()
