"""End-to-end driver: federated training of a transformer LM with K-decay.

Uses the SAME model stack as the assigned architectures (a reduced qwen2
config by default; pass --arch/--layers/--d-model to scale up to ~100M) and
the same FedAvg engine as the paper experiments, over synthetic non-IID
client token streams.

    PYTHONPATH=src python examples/train_federated_lm.py \
        --rounds 100 --layers 4 --d-model 256        # CPU-quick
    PYTHONPATH=src python examples/train_federated_lm.py \
        --rounds 300 --layers 8 --d-model 768 --vocab 8192   # ~100M params
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_arch
from repro.configs.base import FedConfig, RuntimeModelConfig
from repro.core import FedAvgTrainer, RuntimeModel
from repro.data import make_lm_clients
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k0", type=int, default=8)
    ap.add_argument("--k-schedule", default="rounds",
                    choices=("fixed", "rounds", "error", "step", "cosine", "dsgd"))
    ap.add_argument("--server-optimizer", default="avg",
                    choices=("avg", "fedadam", "fedavgm", "fedyogi"))
    ap.add_argument("--aggregator", default="mean",
                    choices=("mean", "kernel", "median", "trimmed_mean"))
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    base = get_arch(args.arch).reduced()
    heads = max(base.num_heads, 4)
    cfg = dataclasses.replace(
        base, num_layers=args.layers, d_model=args.d_model,
        head_dim=args.d_model // heads, d_ff=4 * args.d_model,
        vocab_size=args.vocab)
    n_params = registry.param_count(cfg)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={n_params:,}")

    data = make_lm_clients(np.random.default_rng(0), num_clients=24,
                           vocab=cfg.vocab_size, seq_len=args.seq)
    model_loss = registry.loss_fn(cfg, moe_path="dense")
    loss_fn = lambda p, b: model_loss(p, {"tokens": b["x"]})

    fed = FedConfig(total_clients=24, clients_per_round=6, rounds=args.rounds,
                    k0=args.k0, eta0=0.05, batch_size=8, loss_window=8,
                    k_schedule=args.k_schedule,
                    server_optimizer=args.server_optimizer,
                    aggregator=args.aggregator)
    rt = RuntimeModel(n_params * 32 / 1e6, RuntimeModelConfig(beta_seconds=0.05),
                      fed.clients_per_round)
    params = registry.init(jax.random.PRNGKey(0), cfg)
    trainer = FedAvgTrainer(loss_fn, params, data, fed, rt)
    h = trainer.run(args.rounds, verbose=False)
    for r in range(0, args.rounds, max(args.rounds // 10, 1)):
        print(f"round {h.rounds[r]:4d} K={h.k[r]:3d} "
              f"loss={h.train_loss[r]:.4f} simW={h.wall_clock_s[r]:.0f}s")
    print(f"final: loss={h.train_loss[-1]:.4f} (from {h.train_loss[0]:.4f}) "
          f"steps={h.sgd_steps[-1]} simW={h.wall_clock_s[-1]:.0f}s")

    if args.checkpoint:
        save_checkpoint(args.checkpoint, trainer.params,
                        meta={"rounds": args.rounds, "arch": cfg.name,
                              "k_schedule": args.k_schedule})
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
